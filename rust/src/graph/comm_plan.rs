//! Comm-plan IR: the scheme-neutral description of one tensor-group's
//! synchronization, and the planners that produce it.
//!
//! dPRO's accuracy claim rests on modeling *fine-grained* communication ops
//! per scheme (paper §4.1), but scheme logic must not leak across layers:
//! a [`CommPlanner`] turns one tensor group into a [`GroupPlan`] — a small
//! DAG of [`Stage`]s (op kind, device, duration, byte count, dependencies)
//! — and exactly one generic lowering routine ([`build_group_comm`])
//! materializes that plan into the global DFG. The from-scratch builder
//! ([`crate::graph::build`]) and the in-place splicer
//! ([`crate::graph::mutable::MutableGraph`]) both call the same routine, so
//! an incrementally rewritten group stays structurally identical to a
//! fresh build, for *every* scheme.
//!
//! The optimizer and the replay engines never look at the scheme enum:
//! they key off [`PlanProps`] derived from the lowered plan itself (stage
//! count, uses-servers, critical-path wire bytes) — see
//! [`plan_props`].
//!
//! ## Invariants every planner must uphold
//!
//! 1. **Deps point backwards**: a stage depends only on the group's In ops
//!    or on *earlier* stages, so stage order is a topological order of the
//!    chain and the incremental replayer's canonical ranks (creation order
//!    within a chain) stay dependency-consistent.
//! 2. **Send/Recv pairing**: a `tx` tag is used by exactly two stages, the
//!    `Send` first; lowering assigns them one shared transaction id (the
//!    profiler joins SEND↔RECV by that id, §4.2).
//! 3. **Every worker gets a tail**: at least one stage per worker carries
//!    `out_for`, so each worker's Out op (and its update) is reachable.
//! 4. **Durations affine in bytes**: every duration is `α + β·bytes` of
//!    the cost model, which is what lets the partial-replay probe engines
//!    ([`crate::replay::partial`]) answer `t_sync` queries without builds.
//!
//! [`GroupPlan::validate`] checks 1–3 (debug builds validate every
//! lowering).

use std::collections::HashMap;

use crate::config::{ClusterSpec, CommScheme, JobSpec};
use crate::graph::build::CostProvider;
use crate::graph::dfg::{DeviceKey, Dfg, Node, NodeId, OpKind, TensorMeta, COORD_PROC};
use crate::util::Us;

/// Dependency of a [`Stage`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dep {
    /// Worker `w`'s In virtual op (the group's gradient is ready there).
    In(u16),
    /// Every worker's In op (collective negotiation waits for all).
    AllIn,
    /// An earlier stage of the same plan (index into [`GroupPlan::stages`]).
    Stage(u32),
}

/// One fine-grained communication op of a group's synchronization plan.
#[derive(Clone, Debug)]
pub struct Stage {
    /// DFG node name (empty when the plan is built nameless).
    pub name: String,
    /// Fine-grained communication op kind.
    pub kind: OpKind,
    /// Execution resource the op serializes on.
    pub device: DeviceKey,
    /// Expected duration (us) from the cost provider.
    pub duration: Us,
    /// Worker the op belongs to (for per-worker accounting).
    pub owner: u16,
    /// Process that executes and timestamps the op (worker id,
    /// `n_workers + s` for server `s`, [`COORD_PROC`] for the coordinator).
    pub proc: u16,
    /// Bytes this op moves/touches (recorded in the node's `TensorMeta`).
    pub bytes: f64,
    /// Send↔Recv pairing tag, local to this plan; stages sharing a tag get
    /// one transaction id at lowering time.
    pub tx: Option<u32>,
    /// Backward-only dependencies (In ops or earlier stages).
    pub deps: Vec<Dep>,
    /// `Some(w)`: this stage is a chain tail feeding worker `w`'s Out op.
    pub out_for: Option<u16>,
}

/// The scheme-neutral synchronization plan of one tensor group.
#[derive(Clone, Debug, Default)]
pub struct GroupPlan {
    /// The plan's stages, in a topological order (deps point backwards).
    pub stages: Vec<Stage>,
}

impl GroupPlan {
    /// Append a stage, returning its index for later `Dep::Stage` refs.
    pub fn push(&mut self, stage: Stage) -> u32 {
        self.stages.push(stage);
        (self.stages.len() - 1) as u32
    }

    /// Check the planner invariants (module docs §1–3).
    pub fn validate(&self, n_workers: usize) -> Result<(), String> {
        // per tx tag: (opening Send's stage index, closed by a Recv yet?)
        let mut tx_seen: HashMap<u32, (usize, bool)> = HashMap::new();
        let mut covered = vec![false; n_workers];
        for (i, st) in self.stages.iter().enumerate() {
            for &d in &st.deps {
                match d {
                    Dep::In(w) => {
                        if w as usize >= n_workers {
                            return Err(format!("stage {i} deps In({w}) out of range"));
                        }
                    }
                    Dep::AllIn => {}
                    Dep::Stage(s) => {
                        if s as usize >= i {
                            return Err(format!("stage {i} deps forward on stage {s}"));
                        }
                    }
                }
            }
            if let Some(tag) = st.tx {
                match tx_seen.get(&tag).copied() {
                    None => {
                        if st.kind != OpKind::Send {
                            return Err(format!("tx tag {tag} opened by non-Send stage {i}"));
                        }
                        tx_seen.insert(tag, (i, false));
                    }
                    Some((send_idx, false)) => {
                        if st.kind != OpKind::Recv {
                            return Err(format!("tx tag {tag} closed by non-Recv stage {i}"));
                        }
                        // pairing must be causal, not just positional: the
                        // Recv has to wait for its Send or the replayer
                        // starts it before the data was ever posted
                        if !st.deps.contains(&Dep::Stage(send_idx as u32)) {
                            return Err(format!(
                                "tx tag {tag}: Recv stage {i} does not depend on its \
                                 Send stage {send_idx}"
                            ));
                        }
                        tx_seen.insert(tag, (send_idx, true));
                    }
                    Some((_, true)) => {
                        return Err(format!("tx tag {tag} used more than twice"))
                    }
                }
            }
            if let Some(w) = st.out_for {
                if w as usize >= n_workers {
                    return Err(format!("stage {i} out_for({w}) out of range"));
                }
                covered[w as usize] = true;
            }
        }
        if let Some((tag, _)) = tx_seen.iter().find(|(_, &(_, closed))| !closed) {
            return Err(format!("tx tag {tag} has no matching Recv"));
        }
        if let Some(w) = covered.iter().position(|&c| !c) {
            return Err(format!("no chain tail feeds worker {w}'s Out op"));
        }
        Ok(())
    }

    /// Whether any stage runs on a parameter-server process.
    pub fn uses_servers(&self) -> bool {
        self.stages.iter().any(|s| matches!(s.device, DeviceKey::PsCpu(_)))
    }

    /// Longest path through the stage DAG, weighting `Send` stages by
    /// their byte count: the wire bytes a gradient byte must traverse
    /// end-to-end (the "algorithm bandwidth" denominator coarse models
    /// divide by).
    pub fn critical_path_send_bytes(&self) -> f64 {
        let mut cp = vec![0.0f64; self.stages.len()];
        let mut best = 0.0f64;
        for (i, st) in self.stages.iter().enumerate() {
            let mut upstream = 0.0f64;
            for &d in &st.deps {
                if let Dep::Stage(s) = d {
                    upstream = upstream.max(cp[s as usize]);
                }
            }
            let w = if st.kind == OpKind::Send { st.bytes } else { 0.0 };
            cp[i] = upstream + w;
            best = best.max(cp[i]);
        }
        best
    }
}

/// Everything a planner may read while planning one group. Planners never
/// touch `JobSpec` directly — the context carries the group-local facts,
/// which is what lets [`plan_props`] probe a scheme without a real plan.
pub struct PlanCtx<'a> {
    /// Cluster layout (workers, machines, network).
    pub cluster: &'a ClusterSpec,
    /// Duration oracle for compute/wire/aggregation stages.
    pub cost: &'a dyn CostProvider,
    /// Whether to materialize node names (false on the nameless fast path).
    pub with_names: bool,
    /// Comm-group index (naming only; never used for placement).
    pub gi: usize,
    /// Fused-tensor bytes of the whole group.
    pub gbytes: f64,
    /// Partition count (>= 1).
    pub k: usize,
    /// First (stable) tensor id of the group — the server-placement key:
    /// tensor ids survive tensor fusion, plan indices do not, so in-place
    /// splices and fresh rebuilds agree on placement.
    pub first_tensor: u32,
}

impl PlanCtx<'_> {
    /// Build a node name, or the empty string on the nameless fast path.
    fn name(&self, f: impl FnOnce() -> String) -> String {
        if self.with_names {
            f()
        } else {
            String::new()
        }
    }
}

/// The symmetry a planner's lowered plans are guaranteed to exhibit —
/// what the tiered replayer ([`crate::replay::tiered`]) is allowed to
/// exploit. Declaring a symmetry is a *promise about the plan's shape*,
/// not about durations: the tiered engine still verifies the claim
/// structurally (and against effective durations) before deriving any
/// timeline, so an over-eager declaration costs a fallback, never a
/// wrong answer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanSymmetry {
    /// No exploitable symmetry (the safe default). PS-family plans live
    /// here: every endpoint's pull serializes on the *shared* server
    /// device, so per-machine timelines are arithmetic shifts of each
    /// other in queue position, not plain time translations.
    None,
    /// Rotating the machine index (and every worker/device index with
    /// it) maps the plan onto itself: machine `k`'s timeline equals
    /// machine 0's. True for the ring-structured collective schemes,
    /// whose only cross-machine couplings are the ring hops (uniform
    /// by construction) and the shared negotiate stage (feeds all
    /// machines identically).
    MachineRotation,
}

/// A communication scheme: plans one tensor group's synchronization.
/// Implementations own *all* scheme-specific knowledge; everything
/// downstream of [`build_group_comm`] is scheme-blind.
pub trait CommPlanner {
    /// Human-readable scheme name (reports/diagnostics).
    fn scheme(&self) -> &'static str;
    /// The full synchronization plan of one tensor group.
    fn plan_group(&self, ctx: &PlanCtx) -> GroupPlan;
    /// The symmetry this scheme's plans exhibit (see [`PlanSymmetry`]).
    /// Override when adding a scheme whose per-machine programs are
    /// rotations of each other; the default opts out of tiered replay.
    fn symmetry(&self) -> PlanSymmetry {
        PlanSymmetry::None
    }
}

/// The declared symmetry of a job's scheme (tiered-replay entry point).
pub fn plan_symmetry(scheme: &CommScheme) -> PlanSymmetry {
    planner_for(scheme).symmetry()
}

/// The planner for a job's scheme — the only variant dispatch outside
/// `config`.
pub fn planner_for(scheme: &CommScheme) -> Box<dyn CommPlanner> {
    match scheme {
        CommScheme::AllReduce(_) => Box::new(HierAllReduce),
        CommScheme::Ring(_) => Box::new(RingAllReduce),
        CommScheme::Ps(ps) => Box::new(PsPushPull { n_servers: ps.n_servers.max(1) }),
        CommScheme::PsTree(ps) => Box::new(PsTree { n_servers: ps.n_servers.max(1) }),
    }
}

/// Plan-derived scheme properties: what the optimizer's heuristics key off
/// instead of enum matches (ISSUE: "scheme-blind search").
#[derive(Clone, Copy, Debug)]
pub struct PlanProps {
    /// Scheme name the plan came from (diagnostics only).
    pub scheme: &'static str,
    /// Stages one unpartitioned group lowers to.
    pub stages_per_group: usize,
    /// Synchronization routes through PS processes. Partition search is
    /// enabled by default exactly for these schemes: their per-partition
    /// chains pipeline push against pull (paper §5.2).
    pub uses_servers: bool,
    /// Wire bytes on the critical path per gradient byte — the coarse
    /// "algorithm bandwidth" factor (2(n−1)/n for rings, 2 for PS).
    pub critical_path_wire_factor: f64,
}

/// Derive [`PlanProps`] by planning a unit probe group and inspecting the
/// IR — no scheme enum involved, so a new planner gets correct heuristics
/// for free. The probe materializes one group's stages (O(workers ×
/// ring-steps) for the ring schemes); callers invoke it once per
/// search/estimate, where the very next thing they do is build or replay
/// a graph hundreds of times that size — don't call it per node or per
/// round.
pub fn plan_props(spec: &JobSpec) -> PlanProps {
    struct ZeroCost;
    impl CostProvider for ZeroCost {
        fn comp(&self, _: usize, _: u32) -> Us {
            0.0
        }
        fn send(&self, _: f64, _: bool) -> Us {
            0.0
        }
        fn recv(&self, _: f64, _: bool) -> Us {
            0.0
        }
        fn negotiate(&self) -> Us {
            0.0
        }
        fn reduce_local(&self, _: f64, _: usize) -> Us {
            0.0
        }
        fn bcast_local(&self, _: f64, _: usize) -> Us {
            0.0
        }
        fn aggregate(&self, _: f64) -> Us {
            0.0
        }
        fn update(&self, _: f64) -> Us {
            0.0
        }
        fn gpu_collective(&self, _: f64) -> Us {
            0.0
        }
    }
    let planner = planner_for(&spec.scheme);
    let ctx = PlanCtx {
        cluster: &spec.cluster,
        cost: &ZeroCost,
        with_names: false,
        gi: 0,
        gbytes: 1.0,
        k: 1,
        first_tensor: 0,
    };
    let plan = planner.plan_group(&ctx);
    PlanProps {
        scheme: planner.scheme(),
        stages_per_group: plan.stages.len(),
        uses_servers: plan.uses_servers(),
        critical_path_wire_factor: plan.critical_path_send_bytes(),
    }
}

/// Plan + lower the communication topology of one tensor group, appending
/// to `dfg` and wiring from the group's In ops. `out_per_worker` collects
/// the chain tails that feed each worker's Out op; `gnodes` records every
/// created node in canonical creation order. Shared by the full builder
/// ([`crate::graph::build`]) and the in-place comm-chain splice of
/// [`crate::graph::mutable`], so an incrementally rewritten group is
/// structurally identical to a fresh build of the same spec.
#[allow(clippy::too_many_arguments)]
pub(crate) fn build_group_comm(
    dfg: &mut Dfg,
    spec: &JobSpec,
    cost: &dyn CostProvider,
    with_names: bool,
    gi: usize,
    in_ops: &[NodeId],
    out_per_worker: &mut [Vec<NodeId>],
    gnodes: &mut Vec<NodeId>,
    txid: &mut u64,
) {
    let group = &spec.plan.groups[gi];
    let ctx = PlanCtx {
        cluster: &spec.cluster,
        cost,
        with_names,
        gi,
        gbytes: spec.plan.group_bytes(&spec.model, gi),
        k: group.partitions.max(1),
        first_tensor: group.tensors[0],
    };
    let plan = planner_for(&spec.scheme).plan_group(&ctx);
    debug_assert_eq!(plan.validate(spec.cluster.n_workers), Ok(()));
    lower_group_plan(dfg, plan, gi, in_ops, out_per_worker, gnodes, txid);
}

/// The one generic lowering: materialize a [`GroupPlan`] as DFG nodes and
/// edges. Scheme-blind by construction.
pub(crate) fn lower_group_plan(
    dfg: &mut Dfg,
    plan: GroupPlan,
    gi: usize,
    in_ops: &[NodeId],
    out_per_worker: &mut [Vec<NodeId>],
    gnodes: &mut Vec<NodeId>,
    txid: &mut u64,
) {
    let mut tag_tx: HashMap<u32, u64> = HashMap::new();
    let mut ids: Vec<NodeId> = Vec::with_capacity(plan.stages.len());
    for st in plan.stages {
        let tx = st.tx.map(|tag| {
            *tag_tx.entry(tag).or_insert_with(|| {
                let t = *txid;
                *txid += 1;
                t
            })
        });
        let id = dfg.add(Node {
            name: crate::util::intern::intern(&st.name),
            kind: st.kind,
            device: st.device,
            duration: st.duration,
            owner: st.owner,
            proc: st.proc,
            tensor: Some(TensorMeta { tensor_id: gi as u32, bytes: st.bytes }),
            txid: tx,
            template_id: None,
        });
        for &dep in &st.deps {
            match dep {
                Dep::In(w) => {
                    dfg.edge(in_ops[w as usize], id);
                }
                Dep::AllIn => {
                    for &i in in_ops {
                        dfg.edge(i, id);
                    }
                }
                Dep::Stage(s) => {
                    dfg.edge(ids[s as usize], id);
                }
            }
        }
        gnodes.push(id);
        if let Some(w) = st.out_for {
            out_per_worker[w as usize].push(id);
        }
        ids.push(id);
    }
}

// ---------------------------------------------------------------------------
// The four built-in planners.
// ---------------------------------------------------------------------------

/// Shared negotiation stage for the collective (Horovod-family) schemes:
/// the coordinator serializes group scheduling; the op is a delay, not an
/// exclusive resource (Null device never queues).
fn negotiate_stage(ctx: &PlanCtx, plan: &mut GroupPlan) -> u32 {
    let gi = ctx.gi;
    plan.push(Stage {
        name: ctx.name(|| format!("neg.g{gi}")),
        kind: OpKind::Negotiate,
        device: DeviceKey::Null,
        duration: ctx.cost.negotiate(),
        owner: 0,
        proc: COORD_PROC,
        bytes: ctx.gbytes,
        tx: None,
        deps: vec![Dep::AllIn],
        out_for: None,
    })
}

/// One directed hop of a ring (participant `i` → its successor), fully
/// resolved to devices/durations/procs so [`ring_steps`] stays topology-
/// agnostic (machine rings and worker rings differ only in their hops).
struct RingHop {
    dst: usize,
    send_dev: DeviceKey,
    recv_dev: DeviceKey,
    send_dur: Us,
    recv_dur: Us,
    send_owner: u16,
    send_proc: u16,
    recv_owner: u16,
    recv_proc: u16,
}

/// The shared pipelined ring kernel: `steps` chunk steps where participant
/// `i` sends to `hops[i].dst` — each send waits on the chunk received last
/// step (or the participant's seed stage) and on the participant's own
/// previous send (pipelining). Returns the last-received stage per
/// participant. Both AllReduce planners lower through this one loop, so
/// the dependency wiring cannot diverge between them.
#[allow(clippy::too_many_arguments)]
fn ring_steps(
    plan: &mut GroupPlan,
    tag: &mut u32,
    seeds: &[u32],
    hops: &[RingHop],
    chunk: f64,
    steps: usize,
    send_name: impl Fn(usize, usize) -> String,
    recv_name: impl Fn(usize, usize) -> String,
) -> Vec<u32> {
    let n = seeds.len();
    let mut last = seeds.to_vec();
    let mut prev_send: Vec<Option<u32>> = vec![None; n];
    for step in 0..steps {
        let mut this_recv: Vec<u32> = vec![0; n];
        for (i, hop) in hops.iter().enumerate() {
            let t = *tag;
            *tag += 1;
            let mut deps = vec![Dep::Stage(last[i])];
            if let Some(ps) = prev_send[i] {
                deps.push(Dep::Stage(ps));
            }
            let send = plan.push(Stage {
                name: send_name(i, step),
                kind: OpKind::Send,
                device: hop.send_dev,
                duration: hop.send_dur,
                owner: hop.send_owner,
                proc: hop.send_proc,
                bytes: chunk,
                tx: Some(t),
                deps,
                out_for: None,
            });
            this_recv[hop.dst] = plan.push(Stage {
                name: recv_name(hop.dst, step),
                kind: OpKind::Recv,
                device: hop.recv_dev,
                duration: hop.recv_dur,
                owner: hop.recv_owner,
                proc: hop.recv_proc,
                bytes: chunk,
                tx: Some(t),
                deps: vec![Dep::Stage(send)],
                out_for: None,
            });
            prev_send[i] = Some(send);
        }
        last = this_recv;
    }
    last
}

/// Horovod-style hierarchical AllReduce, modeled as NCCL models it: NVLink
/// reduce within each machine, a flat-ring equivalent across machine NICs
/// — `2(N−1)` pipelined chunk steps of `bytes/N` each, so every NIC
/// crossing carries the full `2(N−1)/N × bytes` ring volume with per-chunk
/// latency — then an NVLink broadcast back to local GPUs.
pub struct HierAllReduce;

impl CommPlanner for HierAllReduce {
    fn scheme(&self) -> &'static str {
        "Horovod"
    }

    fn symmetry(&self) -> PlanSymmetry {
        // per-machine programs (NCCL_RS/RED/ring/BCAST/NCCL_AG) are
        // identical modulo machine rotation; the machine ring's hops
        // all span the same rotation distance
        PlanSymmetry::MachineRotation
    }

    fn plan_group(&self, ctx: &PlanCtx) -> GroupPlan {
        let c = ctx.cluster;
        let gi = ctx.gi;
        let m_count = c.n_machines();
        let pbytes = ctx.gbytes / ctx.k as f64;
        let mut plan = GroupPlan::default();
        let neg = negotiate_stage(ctx, &mut plan);
        let mut tag = 0u32;
        for p in 0..ctx.k {
            // per-worker GPU reduce-scatter kernel, then NVLink reduce
            let mut reduced: Vec<u32> = Vec::with_capacity(m_count);
            for m in 0..m_count {
                let gpus = c.workers_on(m);
                let mut rs_ids = Vec::with_capacity(gpus.len());
                for &w in &gpus {
                    rs_ids.push(plan.push(Stage {
                        name: ctx.name(|| format!("w{w}.NCCL_RS.g{gi}.p{p}")),
                        kind: OpKind::Aggregate,
                        device: DeviceKey::Gpu(w as u16),
                        duration: ctx.cost.gpu_collective(pbytes),
                        owner: w as u16,
                        proc: w as u16,
                        bytes: pbytes,
                        tx: None,
                        deps: vec![Dep::Stage(neg)],
                        out_for: None,
                    }));
                }
                reduced.push(plan.push(Stage {
                    name: ctx.name(|| format!("m{m}.RED.g{gi}.p{p}")),
                    kind: OpKind::Aggregate,
                    device: DeviceKey::NvLink(m as u16),
                    duration: ctx.cost.reduce_local(pbytes, gpus.len()),
                    owner: gpus[0] as u16,
                    proc: gpus[0] as u16,
                    bytes: pbytes,
                    tx: None,
                    deps: rs_ids.into_iter().map(Dep::Stage).collect(),
                    out_for: None,
                }));
            }

            // ring across machines: 2(N-1) flat-ring chunk steps of bytes/N
            let mut last_recv = reduced;
            if m_count > 1 {
                let n = c.n_workers;
                let chunk = pbytes / n as f64;
                let hops: Vec<RingHop> = (0..m_count)
                    .map(|m| {
                        let dst = (m + 1) % m_count;
                        RingHop {
                            dst,
                            send_dev: DeviceKey::LinkTx(m as u16),
                            recv_dev: DeviceKey::LinkRx(dst as u16),
                            send_dur: ctx.cost.send(chunk, false),
                            recv_dur: ctx.cost.recv(chunk, false),
                            send_owner: c.workers_on(m)[0] as u16,
                            send_proc: c.workers_on(m)[0] as u16,
                            recv_owner: c.workers_on(dst)[0] as u16,
                            recv_proc: c.workers_on(dst)[0] as u16,
                        }
                    })
                    .collect();
                last_recv = ring_steps(
                    &mut plan,
                    &mut tag,
                    &last_recv,
                    &hops,
                    chunk,
                    2 * (n - 1),
                    |m, step| ctx.name(|| format!("m{m}.SEND.g{gi}.p{p}.s{step}")),
                    |dst, step| ctx.name(|| format!("m{dst}.RECV.g{gi}.p{p}.s{step}")),
                );
            }

            // local broadcast + per-worker GPU all-gather feeding Out
            for m in 0..m_count {
                let gpus = c.workers_on(m);
                let bc = plan.push(Stage {
                    name: ctx.name(|| format!("m{m}.BCAST.g{gi}.p{p}")),
                    kind: OpKind::Aggregate,
                    device: DeviceKey::NvLink(m as u16),
                    duration: ctx.cost.bcast_local(pbytes, gpus.len()),
                    owner: gpus[0] as u16,
                    proc: gpus[0] as u16,
                    bytes: pbytes,
                    tx: None,
                    deps: vec![Dep::Stage(last_recv[m])],
                    out_for: None,
                });
                for w in gpus {
                    plan.push(Stage {
                        name: ctx.name(|| format!("w{w}.NCCL_AG.g{gi}.p{p}")),
                        kind: OpKind::Aggregate,
                        device: DeviceKey::Gpu(w as u16),
                        duration: ctx.cost.gpu_collective(pbytes),
                        owner: w as u16,
                        proc: w as u16,
                        bytes: pbytes,
                        tx: None,
                        deps: vec![Dep::Stage(bc)],
                        out_for: Some(w as u16),
                    });
                }
            }
        }
        plan
    }
}

/// Flat ring AllReduce over *workers*: no NVLink hierarchy — all `n`
/// workers form one ring and run `2(n−1)` pipelined chunk steps of
/// `bytes/n`. Intra-machine hops ride NVLink, machine-boundary hops the
/// NIC; each NIC still carries the `2(n−1)/n × bytes` ring volume, but the
/// NVLink devices now serialize every intra-machine hop — exactly the
/// hierarchy-blindness this scheme exists to model.
pub struct RingAllReduce;

impl CommPlanner for RingAllReduce {
    fn scheme(&self) -> &'static str {
        "Ring"
    }

    fn symmetry(&self) -> PlanSymmetry {
        // the flat worker ring visits every worker identically; rotating
        // by one machine rotates the ring onto itself (workers are laid
        // out machine-major)
        PlanSymmetry::MachineRotation
    }

    fn plan_group(&self, ctx: &PlanCtx) -> GroupPlan {
        let c = ctx.cluster;
        let gi = ctx.gi;
        let n = c.n_workers;
        let pbytes = ctx.gbytes / ctx.k as f64;
        let mut plan = GroupPlan::default();
        let neg = negotiate_stage(ctx, &mut plan);
        let mut tag = 0u32;
        for p in 0..ctx.k {
            let chunk = pbytes / n as f64;
            // per-worker reduce-scatter kernel seeds the ring
            let mut last: Vec<u32> = (0..n)
                .map(|w| {
                    plan.push(Stage {
                        name: ctx.name(|| format!("w{w}.RING_RS.g{gi}.p{p}")),
                        kind: OpKind::Aggregate,
                        device: DeviceKey::Gpu(w as u16),
                        duration: ctx.cost.gpu_collective(pbytes),
                        owner: w as u16,
                        proc: w as u16,
                        bytes: pbytes,
                        tx: None,
                        deps: vec![Dep::Stage(neg)],
                        out_for: None,
                    })
                })
                .collect();
            if n > 1 {
                let hops: Vec<RingHop> = (0..n)
                    .map(|w| {
                        let dst = (w + 1) % n;
                        let (wm, dm) = (c.machine_of(w), c.machine_of(dst));
                        let intra = wm == dm;
                        RingHop {
                            dst,
                            send_dev: if intra {
                                DeviceKey::NvLink(wm as u16)
                            } else {
                                DeviceKey::LinkTx(wm as u16)
                            },
                            recv_dev: if intra {
                                DeviceKey::NvLink(dm as u16)
                            } else {
                                DeviceKey::LinkRx(dm as u16)
                            },
                            send_dur: ctx.cost.send(chunk, intra),
                            recv_dur: if intra { 0.0 } else { ctx.cost.recv(chunk, false) },
                            send_owner: w as u16,
                            send_proc: w as u16,
                            recv_owner: dst as u16,
                            recv_proc: dst as u16,
                        }
                    })
                    .collect();
                last = ring_steps(
                    &mut plan,
                    &mut tag,
                    &last,
                    &hops,
                    chunk,
                    2 * (n - 1),
                    |w, step| ctx.name(|| format!("w{w}.RSEND.g{gi}.p{p}.s{step}")),
                    |dst, step| ctx.name(|| format!("w{dst}.RRECV.g{gi}.p{p}.s{step}")),
                );
            }
            for (w, &tail) in last.iter().enumerate() {
                plan.push(Stage {
                    name: ctx.name(|| format!("w{w}.RING_AG.g{gi}.p{p}")),
                    kind: OpKind::Aggregate,
                    device: DeviceKey::Gpu(w as u16),
                    duration: ctx.cost.gpu_collective(pbytes),
                    owner: w as u16,
                    proc: w as u16,
                    bytes: pbytes,
                    tx: None,
                    deps: vec![Dep::Stage(tail)],
                    out_for: Some(w as u16),
                });
            }
        }
        plan
    }
}

/// One PS client endpoint for [`push_pull_stages`]: whoever pushes a
/// partition to the server and pulls it back — a worker for flat PS, a
/// machine representative for tree PS.
struct PsEndpoint {
    /// The endpoint's already-created seed stage holding the local
    /// contribution (D2H for flat PS, the machine-local reduce for tree).
    seed: u32,
    owner: u16,
    proc: u16,
    machine: usize,
}

/// The five server-facing stage roles, for the naming callback.
enum PsWire {
    PushSend,
    PushRecv,
    Agg,
    PullSend,
    PullRecv,
}

/// The shared PS round trip: every endpoint pushes (SEND → RECV →
/// server-CPU aggregate), and — synchronous training — every pull waits on
/// *all* aggregates before coming back (SEND → RECV). Intra-machine hops
/// ride NVLink with zero-duration recvs, inter-machine hops the NIC. Both
/// PS planners lower through this one routine, so the wiring and the
/// device conventions cannot diverge between them. Returns each
/// endpoint's PULL_RECV stage for the planner-specific tail (H2D fan-out
/// or broadcast).
#[allow(clippy::too_many_arguments)]
fn push_pull_stages(
    plan: &mut GroupPlan,
    ctx: &PlanCtx,
    tag: &mut u32,
    server_machine: usize,
    sproc: u16,
    server: u16,
    pbytes: f64,
    endpoints: &[PsEndpoint],
    name: impl Fn(PsWire, usize) -> String,
) -> Vec<u32> {
    let mut aggs: Vec<u32> = Vec::with_capacity(endpoints.len());
    for (i, ep) in endpoints.iter().enumerate() {
        let intra = ep.machine == server_machine;
        let t = *tag;
        *tag += 1;
        let push_send = plan.push(Stage {
            name: name(PsWire::PushSend, i),
            kind: OpKind::Send,
            device: if intra {
                DeviceKey::NvLink(ep.machine as u16)
            } else {
                DeviceKey::LinkTx(ep.machine as u16)
            },
            duration: ctx.cost.send(pbytes, intra),
            owner: ep.owner,
            proc: ep.proc,
            bytes: pbytes,
            tx: Some(t),
            deps: vec![Dep::Stage(ep.seed)],
            out_for: None,
        });
        let push_recv = plan.push(Stage {
            name: name(PsWire::PushRecv, i),
            kind: OpKind::Recv,
            device: if intra {
                DeviceKey::NvLink(server_machine as u16)
            } else {
                DeviceKey::LinkRx(server_machine as u16)
            },
            duration: if intra { 0.0 } else { ctx.cost.recv(pbytes, false) },
            owner: ep.owner,
            proc: sproc,
            bytes: pbytes,
            tx: Some(t),
            deps: vec![Dep::Stage(push_send)],
            out_for: None,
        });
        aggs.push(plan.push(Stage {
            name: name(PsWire::Agg, i),
            kind: OpKind::Aggregate,
            device: DeviceKey::PsCpu(server),
            duration: ctx.cost.aggregate(pbytes),
            owner: ep.owner,
            proc: sproc,
            bytes: pbytes,
            tx: None,
            deps: vec![Dep::Stage(push_recv)],
            out_for: None,
        }));
    }

    let mut pulls: Vec<u32> = Vec::with_capacity(endpoints.len());
    for (i, ep) in endpoints.iter().enumerate() {
        let intra = ep.machine == server_machine;
        let t = *tag;
        *tag += 1;
        let pull_send = plan.push(Stage {
            name: name(PsWire::PullSend, i),
            kind: OpKind::Send,
            device: if intra {
                DeviceKey::NvLink(server_machine as u16)
            } else {
                DeviceKey::LinkTx(server_machine as u16)
            },
            duration: ctx.cost.send(pbytes, intra),
            owner: ep.owner,
            proc: ep.proc,
            bytes: pbytes,
            tx: Some(t),
            deps: aggs.iter().map(|&a| Dep::Stage(a)).collect(),
            out_for: None,
        });
        pulls.push(plan.push(Stage {
            name: name(PsWire::PullRecv, i),
            kind: OpKind::Recv,
            device: if intra {
                DeviceKey::NvLink(ep.machine as u16)
            } else {
                DeviceKey::LinkRx(ep.machine as u16)
            },
            duration: if intra { 0.0 } else { ctx.cost.recv(pbytes, false) },
            owner: ep.owner,
            proc: ep.proc,
            bytes: pbytes,
            tx: Some(t),
            deps: vec![Dep::Stage(pull_send)],
            out_for: None,
        }));
    }
    pulls
}

/// BytePS-style flat PS: every worker PUSHes each partition to its server
/// (D2H → SEND → RECV → server-CPU aggregate), and once all contributions
/// are in, PULLs it back (SEND → RECV → H2D). Server placement is keyed by
/// the group's first tensor id (stable under fusion).
pub struct PsPushPull {
    /// Parameter-server process count.
    pub n_servers: usize,
}

impl CommPlanner for PsPushPull {
    fn scheme(&self) -> &'static str {
        "BytePS"
    }

    fn plan_group(&self, ctx: &PlanCtx) -> GroupPlan {
        let c = ctx.cluster;
        let gi = ctx.gi;
        let n_workers = c.n_workers;
        let pbytes = ctx.gbytes / ctx.k as f64;
        let mut plan = GroupPlan::default();
        let mut tag = 0u32;
        for p in 0..ctx.k {
            let server = (ctx.first_tensor as usize + p) % self.n_servers;
            // PS `server` runs on machine `server` (colocated mode).
            let server_machine = server % c.n_machines().max(1);
            let sproc = (n_workers + server) as u16;

            // every worker stages its contribution (D2H) and is its own
            // push/pull endpoint
            let endpoints: Vec<PsEndpoint> = (0..n_workers)
                .map(|w| {
                    let d2h = plan.push(Stage {
                        name: ctx.name(|| format!("w{w}.D2H.g{gi}.p{p}")),
                        kind: OpKind::Aggregate,
                        device: DeviceKey::Gpu(w as u16),
                        duration: ctx.cost.gpu_collective(pbytes),
                        owner: w as u16,
                        proc: w as u16,
                        bytes: pbytes,
                        tx: None,
                        deps: vec![Dep::In(w as u16)],
                        out_for: None,
                    });
                    PsEndpoint {
                        seed: d2h,
                        owner: w as u16,
                        proc: w as u16,
                        machine: c.machine_of(w),
                    }
                })
                .collect();

            let pulls = push_pull_stages(
                &mut plan,
                ctx,
                &mut tag,
                server_machine,
                sproc,
                server as u16,
                pbytes,
                &endpoints,
                |wire, w| {
                    ctx.name(|| match wire {
                        PsWire::PushSend => format!("w{w}.PUSH_SEND.g{gi}.p{p}"),
                        PsWire::PushRecv => format!("s{server}.PUSH_RECV.g{gi}.p{p}.w{w}"),
                        PsWire::Agg => format!("s{server}.AGG.g{gi}.p{p}.w{w}"),
                        PsWire::PullSend => format!("s{server}.PULL_SEND.g{gi}.p{p}.w{w}"),
                        PsWire::PullRecv => format!("w{w}.PULL_RECV.g{gi}.p{p}"),
                    })
                },
            );

            for (w, &pull_recv) in pulls.iter().enumerate() {
                plan.push(Stage {
                    name: ctx.name(|| format!("w{w}.H2D.g{gi}.p{p}")),
                    kind: OpKind::Aggregate,
                    device: DeviceKey::Gpu(w as u16),
                    duration: ctx.cost.gpu_collective(pbytes),
                    owner: w as u16,
                    proc: w as u16,
                    bytes: pbytes,
                    tx: None,
                    deps: vec![Dep::Stage(pull_recv)],
                    out_for: Some(w as u16),
                });
            }
        }
        plan
    }
}

/// Tree/hierarchical PS: each machine first reduces the partition over
/// NVLink (per-worker D2H → machine-local aggregate), then one
/// representative per *machine* pushes to the server and pulls the result
/// back, and an NVLink broadcast + per-worker H2D fans it out. Cuts the
/// server's ingress from `n_workers` to `n_machines` messages.
pub struct PsTree {
    /// Parameter-server process count.
    pub n_servers: usize,
}

impl CommPlanner for PsTree {
    fn scheme(&self) -> &'static str {
        "PS-Tree"
    }

    fn plan_group(&self, ctx: &PlanCtx) -> GroupPlan {
        let c = ctx.cluster;
        let gi = ctx.gi;
        let n_workers = c.n_workers;
        let m_count = c.n_machines();
        let pbytes = ctx.gbytes / ctx.k as f64;
        let mut plan = GroupPlan::default();
        let mut tag = 0u32;
        for p in 0..ctx.k {
            let server = (ctx.first_tensor as usize + p) % self.n_servers;
            let server_machine = server % m_count.max(1);
            let sproc = (n_workers + server) as u16;

            // the tree: per-worker D2H, machine-local NVLink reduce, and
            // one push/pull endpoint per *machine* (its representative)
            let endpoints: Vec<PsEndpoint> = (0..m_count)
                .map(|m| {
                    let gpus = c.workers_on(m);
                    let rep = gpus[0] as u16;
                    let d2h_ids: Vec<u32> = gpus
                        .iter()
                        .map(|&w| {
                            plan.push(Stage {
                                name: ctx.name(|| format!("w{w}.D2H.g{gi}.p{p}")),
                                kind: OpKind::Aggregate,
                                device: DeviceKey::Gpu(w as u16),
                                duration: ctx.cost.gpu_collective(pbytes),
                                owner: w as u16,
                                proc: w as u16,
                                bytes: pbytes,
                                tx: None,
                                deps: vec![Dep::In(w as u16)],
                                out_for: None,
                            })
                        })
                        .collect();
                    let tred = plan.push(Stage {
                        name: ctx.name(|| format!("m{m}.TRED.g{gi}.p{p}")),
                        kind: OpKind::Aggregate,
                        device: DeviceKey::NvLink(m as u16),
                        duration: ctx.cost.reduce_local(pbytes, gpus.len()),
                        owner: rep,
                        proc: rep,
                        bytes: pbytes,
                        tx: None,
                        deps: d2h_ids.into_iter().map(Dep::Stage).collect(),
                        out_for: None,
                    });
                    PsEndpoint { seed: tred, owner: rep, proc: rep, machine: m }
                })
                .collect();

            let pulls = push_pull_stages(
                &mut plan,
                ctx,
                &mut tag,
                server_machine,
                sproc,
                server as u16,
                pbytes,
                &endpoints,
                |wire, m| {
                    ctx.name(|| match wire {
                        PsWire::PushSend => format!("m{m}.TPUSH_SEND.g{gi}.p{p}"),
                        PsWire::PushRecv => format!("s{server}.TPUSH_RECV.g{gi}.p{p}.m{m}"),
                        PsWire::Agg => format!("s{server}.TAGG.g{gi}.p{p}.m{m}"),
                        PsWire::PullSend => format!("s{server}.TPULL_SEND.g{gi}.p{p}.m{m}"),
                        PsWire::PullRecv => format!("m{m}.TPULL_RECV.g{gi}.p{p}"),
                    })
                },
            );

            // machine-local broadcast + per-worker H2D fan-out feeding Out
            for (m, &pull_recv) in pulls.iter().enumerate() {
                let gpus = c.workers_on(m);
                let rep = gpus[0] as u16;
                let tbc = plan.push(Stage {
                    name: ctx.name(|| format!("m{m}.TBC.g{gi}.p{p}")),
                    kind: OpKind::Aggregate,
                    device: DeviceKey::NvLink(m as u16),
                    duration: ctx.cost.bcast_local(pbytes, gpus.len()),
                    owner: rep,
                    proc: rep,
                    bytes: pbytes,
                    tx: None,
                    deps: vec![Dep::Stage(pull_recv)],
                    out_for: None,
                });
                for w in gpus {
                    plan.push(Stage {
                        name: ctx.name(|| format!("w{w}.H2D.g{gi}.p{p}")),
                        kind: OpKind::Aggregate,
                        device: DeviceKey::Gpu(w as u16),
                        duration: ctx.cost.gpu_collective(pbytes),
                        owner: w as u16,
                        proc: w as u16,
                        bytes: pbytes,
                        tx: None,
                        deps: vec![Dep::Stage(tbc)],
                        out_for: Some(w as u16),
                    });
                }
            }
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterSpec, JobSpec, NetworkSpec, Transport, ALL_SCHEMES};
    use crate::graph::build::AnalyticCost;

    fn spec_for(scheme: &str) -> JobSpec {
        JobSpec::standard("vgg16", scheme, Transport::Rdma)
    }

    fn ctx_plan(scheme: &str, gbytes: f64, k: usize) -> (JobSpec, GroupPlan) {
        let spec = spec_for(scheme);
        let plan = {
            let cost = AnalyticCost::new(&spec);
            let ctx = PlanCtx {
                cluster: &spec.cluster,
                cost: &cost,
                with_names: true,
                gi: 0,
                gbytes,
                k,
                first_tensor: 0,
            };
            planner_for(&spec.scheme).plan_group(&ctx)
        };
        plan.validate(spec.cluster.n_workers).unwrap();
        (spec, plan)
    }

    #[test]
    fn every_scheme_plans_and_validates() {
        for scheme in ALL_SCHEMES {
            let (spec, plan) = ctx_plan(scheme, 8.0e6, 3);
            assert!(!plan.stages.is_empty(), "{scheme}");
            // every worker reachable, all deps backward (validate checked)
            let tails = plan.stages.iter().filter(|s| s.out_for.is_some()).count();
            assert_eq!(tails, spec.cluster.n_workers * 3, "{scheme}: one tail per worker per partition");
        }
    }

    // ---- golden plans: stage counts, kinds, devices, byte splits ----

    #[test]
    fn golden_hier_allreduce_plan() {
        // 16 workers / 2 machines, k=1: 1 neg + per machine (8 RS + 1 RED)
        // + 2(16-1)=30 steps × 2 machines × (send+recv) + per machine
        // (1 BCAST + 8 AG)
        let (spec, plan) = ctx_plan("horovod", 16.0e6, 1);
        let n = spec.cluster.n_workers;
        assert_eq!(plan.stages.len(), 1 + 2 * 9 + 30 * 2 * 2 + 2 * 9);
        assert_eq!(plan.stages[0].kind, OpKind::Negotiate);
        assert_eq!(plan.stages[0].bytes, 16.0e6);
        let sends: Vec<&Stage> =
            plan.stages.iter().filter(|s| s.kind == OpKind::Send).collect();
        assert_eq!(sends.len(), 30 * 2);
        for s in &sends {
            assert!(matches!(s.device, DeviceKey::LinkTx(_)), "ring sends cross NICs");
            assert_eq!(s.bytes, 16.0e6 / n as f64, "chunk = bytes/N");
        }
        assert!(!plan.uses_servers());
        // ring volume on the critical path: 2(N-1)/N of the bytes
        let f = plan.critical_path_send_bytes() / 16.0e6;
        let expect = 2.0 * (n as f64 - 1.0) / n as f64;
        assert!((f - expect).abs() < 1e-9, "factor {f} vs {expect}");
    }

    #[test]
    fn golden_ring_plan() {
        // flat worker ring: 1 neg + 16 RS + 2(16-1)=30 steps × 16 workers
        // × (send+recv) + 16 AG
        let (spec, plan) = ctx_plan("ring", 16.0e6, 1);
        let n = spec.cluster.n_workers;
        assert_eq!(plan.stages.len(), 1 + n + 30 * n * 2 + n);
        let sends: Vec<&Stage> =
            plan.stages.iter().filter(|s| s.kind == OpKind::Send).collect();
        assert_eq!(sends.len(), 30 * n);
        // hierarchy-blind: most hops stay on NVLink, machine-boundary hops
        // (2 of 16 per step) take the NIC
        let nic = sends.iter().filter(|s| matches!(s.device, DeviceKey::LinkTx(_))).count();
        let nvl = sends.iter().filter(|s| matches!(s.device, DeviceKey::NvLink(_))).count();
        assert_eq!(nic, 30 * 2);
        assert_eq!(nvl, 30 * (n - 2));
        for s in &sends {
            assert_eq!(s.bytes, 16.0e6 / n as f64, "chunk = bytes/n");
        }
        assert!(!plan.uses_servers());
        let f = plan.critical_path_send_bytes() / 16.0e6;
        let expect = 2.0 * (n as f64 - 1.0) / n as f64;
        assert!((f - expect).abs() < 1e-9, "factor {f} vs {expect}");
    }

    #[test]
    fn golden_ps_plan() {
        // per worker: D2H, PUSH_SEND, PUSH_RECV, AGG then PULL_SEND,
        // PULL_RECV, H2D — 7 stages × 16 workers, k=2 doubles it
        let (spec, plan) = ctx_plan("byteps", 8.0e6, 2);
        let n = spec.cluster.n_workers;
        assert_eq!(plan.stages.len(), 7 * n * 2);
        let aggs = plan
            .stages
            .iter()
            .filter(|s| matches!(s.device, DeviceKey::PsCpu(_)))
            .count();
        assert_eq!(aggs, n * 2, "one server aggregate per worker per partition");
        assert!(plan.uses_servers());
        // partitions split the bytes evenly
        for s in plan.stages.iter().filter(|s| s.kind == OpKind::Send) {
            assert_eq!(s.bytes, 4.0e6, "pbytes = gbytes/k");
        }
        // push + pull on the critical path
        let f = plan.critical_path_send_bytes() / 4.0e6;
        assert!((f - 2.0).abs() < 1e-9, "factor {f}");
        // k=2 places partitions on different servers
        let servers: std::collections::HashSet<u16> = plan
            .stages
            .iter()
            .filter_map(|s| match s.device {
                DeviceKey::PsCpu(x) => Some(x),
                _ => None,
            })
            .collect();
        assert_eq!(servers.len(), 2);
    }

    #[test]
    fn golden_ps_tree_plan() {
        // per machine: 8 D2H + TRED + TPUSH_SEND + TPUSH_RECV + TAGG, then
        // TPULL_SEND + TPULL_RECV + TBC + 8 H2D — (8+4) + (3+8) per machine
        let (spec, plan) = ctx_plan("ps-tree", 8.0e6, 1);
        let m = spec.cluster.n_machines();
        let g = spec.cluster.gpus_per_machine;
        assert_eq!(plan.stages.len(), m * (g + 4) + m * (3 + g));
        // the tree: server ingress is per machine, not per worker
        let aggs = plan
            .stages
            .iter()
            .filter(|s| matches!(s.device, DeviceKey::PsCpu(_)))
            .count();
        assert_eq!(aggs, m);
        let sends = plan.stages.iter().filter(|s| s.kind == OpKind::Send).count();
        assert_eq!(sends, 2 * m, "one push + one pull per machine");
        assert!(plan.uses_servers());
        let f = plan.critical_path_send_bytes() / 8.0e6;
        assert!((f - 2.0).abs() < 1e-9, "factor {f}");
        // machine-local reduce sized to the machine's GPU count
        let treds = plan
            .stages
            .iter()
            .filter(|s| matches!(s.device, DeviceKey::NvLink(_)) && s.kind == OpKind::Aggregate)
            .count();
        assert_eq!(treds, 2 * m, "one TRED + one TBC per machine");
    }

    #[test]
    fn plan_props_agree_with_scheme_declarations() {
        for scheme in ALL_SCHEMES {
            let spec = spec_for(scheme);
            let props = plan_props(&spec);
            assert_eq!(
                props.uses_servers,
                spec.scheme.uses_servers(),
                "{scheme}: IR-derived and declared uses_servers diverge"
            );
            assert!(props.stages_per_group > 0, "{scheme}");
            assert!(
                props.critical_path_wire_factor > 0.0
                    && props.critical_path_wire_factor <= 2.0 + 1e-9,
                "{scheme}: factor {}",
                props.critical_path_wire_factor
            );
        }
    }

    #[test]
    fn single_machine_plans_have_no_nic_traffic() {
        for scheme in ["horovod", "ring"] {
            let model = crate::models::by_name("vgg16", 8).unwrap();
            let cluster = ClusterSpec::new(8, 8, NetworkSpec::rdma_100g());
            let spec = JobSpec::with_scheme_name(model, cluster, scheme);
            let cost = AnalyticCost::new(&spec);
            let ctx = PlanCtx {
                cluster: &spec.cluster,
                cost: &cost,
                with_names: false,
                gi: 0,
                gbytes: 4.0e6,
                k: 1,
                first_tensor: 0,
            };
            let plan = planner_for(&spec.scheme).plan_group(&ctx);
            plan.validate(8).unwrap();
            let nic = plan
                .stages
                .iter()
                .filter(|s| matches!(s.device, DeviceKey::LinkTx(_) | DeviceKey::LinkRx(_)))
                .count();
            assert_eq!(nic, 0, "{scheme}: single machine must not touch the NIC");
        }
    }

    #[test]
    fn validate_rejects_broken_plans() {
        let mut plan = GroupPlan::default();
        plan.push(Stage {
            name: String::new(),
            kind: OpKind::Aggregate,
            device: DeviceKey::Gpu(0),
            duration: 1.0,
            owner: 0,
            proc: 0,
            bytes: 1.0,
            tx: None,
            deps: vec![Dep::Stage(5)], // forward reference
            out_for: Some(0),
        });
        assert!(plan.validate(1).is_err());
        let mut plan = GroupPlan::default();
        plan.push(Stage {
            name: String::new(),
            kind: OpKind::Recv, // tx opened by a Recv
            device: DeviceKey::LinkRx(0),
            duration: 1.0,
            owner: 0,
            proc: 0,
            bytes: 1.0,
            tx: Some(0),
            deps: vec![],
            out_for: Some(0),
        });
        assert!(plan.validate(1).is_err());
        // a worker with no chain tail
        let mut plan = GroupPlan::default();
        plan.push(Stage {
            name: String::new(),
            kind: OpKind::Aggregate,
            device: DeviceKey::Gpu(0),
            duration: 1.0,
            owner: 0,
            proc: 0,
            bytes: 1.0,
            tx: None,
            deps: vec![],
            out_for: Some(0),
        });
        assert!(plan.validate(2).is_err());
        // a tx-paired Recv that does not causally depend on its Send
        let mut plan = GroupPlan::default();
        plan.push(Stage {
            name: String::new(),
            kind: OpKind::Send,
            device: DeviceKey::LinkTx(0),
            duration: 1.0,
            owner: 0,
            proc: 0,
            bytes: 1.0,
            tx: Some(7),
            deps: vec![],
            out_for: None,
        });
        plan.push(Stage {
            name: String::new(),
            kind: OpKind::Recv,
            device: DeviceKey::LinkRx(0),
            duration: 1.0,
            owner: 0,
            proc: 0,
            bytes: 1.0,
            tx: Some(7),
            deps: vec![], // missing Dep::Stage(0)
            out_for: Some(0),
        });
        assert!(plan.validate(1).is_err());
    }
}
