//! Global-DFG construction (paper §4.1): connect per-worker local DFGs with
//! the fine-grained communication topology of the chosen synchronization
//! scheme, via In/Out virtual ops and producer/consumer (SEND/RECV) pairs
//! labelled with transaction ids.
//!
//! This file is *scheme-blind*: the per-group communication topology comes
//! from the scheme's [`crate::graph::comm_plan::CommPlanner`] through the
//! shared lowering routine [`crate::graph::comm_plan::build_group_comm`].
//!
//! Op names are deterministic and shared with the testbed's trace emitter,
//! so measured traces can be joined back onto the skeleton by name.

use std::collections::HashMap;

use crate::config::JobSpec;
use crate::graph::comm_plan::build_group_comm;
use crate::graph::dfg::{DeviceKey, Dfg, Node, NodeId, OpKind, TensorMeta};
use crate::util::Us;

thread_local! {
    /// Count of full global-DFG constructions (named and nameless) on this
    /// thread. The optimizer's hot loop must perform none after its setup
    /// phase — the incremental subsystem ([`crate::graph::mutable`]) edits
    /// the graph in place instead — and tests assert that through this
    /// counter. Thread-local so concurrently running tests cannot pollute
    /// each other's deltas.
    static BUILD_COUNT: std::cell::Cell<usize> = std::cell::Cell::new(0);
}

/// Monotonic number of global-DFG constructions so far on this thread.
pub fn build_count() -> usize {
    BUILD_COUNT.with(|c| c.get())
}

/// Supplies op durations during construction. `AnalyticCost` derives them
/// from the cluster spec; the profiler swaps in measured averages.
pub trait CostProvider {
    /// Duration of *fusion group* `group_id` on `worker` (singleton groups
    /// are plain template ops).
    fn comp(&self, worker: usize, group_id: u32) -> Us;
    /// TX-side occupancy of sending `bytes` (one message).
    fn send(&self, bytes: f64, intra_machine: bool) -> Us;
    /// RX-side occupancy of receiving `bytes` (one message).
    fn recv(&self, bytes: f64, intra_machine: bool) -> Us;
    /// Coordinator negotiation delay for one tensor group (AllReduce).
    fn negotiate(&self) -> Us;
    /// NVLink reduce of `bytes` across the GPUs of one machine.
    fn reduce_local(&self, bytes: f64, n_gpus: usize) -> Us;
    /// NVLink broadcast of `bytes` to the GPUs of one machine.
    fn bcast_local(&self, bytes: f64, n_gpus: usize) -> Us;
    /// PS server-side aggregation of one pushed partition.
    fn aggregate(&self, bytes: f64) -> Us;
    /// Optimizer update for `bytes` of parameters on a worker.
    fn update(&self, bytes: f64) -> Us;
    /// GPU-side kernel time a collective/copy costs *on the worker's GPU*
    /// (NCCL reduce-scatter/all-gather kernels, D2H/H2D staging): the
    /// compute/communication contention term Daydream does not model.
    fn gpu_collective(&self, bytes: f64) -> Us;
}

/// Cost model implied by the job spec (no noise — expectation values).
pub struct AnalyticCost<'a> {
    /// The job whose model/cluster parameters define the costs.
    pub spec: &'a JobSpec,
}

impl<'a> AnalyticCost<'a> {
    /// Wrap a job spec as a cost provider.
    pub fn new(spec: &'a JobSpec) -> Self {
        AnalyticCost { spec }
    }
}

impl CostProvider for AnalyticCost<'_> {
    fn comp(&self, _worker: usize, group_id: u32) -> Us {
        self.spec.fusion.duration(&self.spec.model, &self.spec.cluster.gpu, group_id as usize)
    }

    fn send(&self, bytes: f64, intra: bool) -> Us {
        let net = &self.spec.cluster.network;
        if intra {
            net.nvlink_time_us(bytes)
        } else {
            net.per_msg_overhead_us() + net.wire_time_us(bytes)
        }
    }

    fn recv(&self, bytes: f64, intra: bool) -> Us {
        let net = &self.spec.cluster.network;
        if intra {
            net.nvlink_time_us(bytes)
        } else {
            net.base_latency_us() + net.wire_time_us(bytes)
        }
    }

    fn negotiate(&self) -> Us {
        // a ready tensor waits on average half a coordinator cycle; 0 for
        // schemes without a coordinator
        self.spec.scheme.cycle_time_us() * 0.5
    }

    fn reduce_local(&self, bytes: f64, n_gpus: usize) -> Us {
        if n_gpus <= 1 {
            return 0.0;
        }
        // ring-reduce within the machine over NVLink
        self.spec.cluster.network.nvlink_time_us(bytes) * 2.0 * (n_gpus - 1) as f64
            / n_gpus as f64
    }

    fn bcast_local(&self, bytes: f64, n_gpus: usize) -> Us {
        if n_gpus <= 1 {
            return 0.0;
        }
        self.spec.cluster.network.nvlink_time_us(bytes)
    }

    fn aggregate(&self, bytes: f64) -> Us {
        self.spec.scheme.agg_bytes_per_s().map_or(0.0, |rate| bytes / rate * 1e6)
    }

    fn update(&self, bytes: f64) -> Us {
        // SGD+momentum: ~4 passes over the parameter bytes, memory-bound.
        let gpu = &self.spec.cluster.gpu;
        gpu.launch_overhead_us + 4.0 * bytes / gpu.mem_bw * 1e6
    }

    fn gpu_collective(&self, bytes: f64) -> Us {
        // kernel launch + stream sync (~90 us) + reduction/copy at ~40 GB/s
        90.0 + bytes / 40.0e9 * 1e6
    }
}

/// The constructed global DFG plus lookup tables used by replay, partial
/// replay and the optimizer.
#[derive(Clone, Debug)]
pub struct GlobalDfg {
    /// The node/edge arena.
    pub dfg: Dfg,
    /// comp node of (worker, fusion-group id); with the default singleton
    /// fusion plan, group id == template op id
    pub comp_node: HashMap<(u16, u32), NodeId>,
    /// all communication-chain node ids of each tensor group (for partial
    /// replay of a tensor's synchronization, paper §5.3)
    pub group_nodes: Vec<Vec<NodeId>>,
    /// Out virtual ops per (worker, group)
    pub group_out: HashMap<(u16, usize), Vec<NodeId>>,
    /// update node per (worker, group)
    pub update_node: HashMap<(u16, usize), NodeId>,
    /// Worker count the graph was built for.
    pub n_workers: usize,
}

/// Build the global DFG for a job. See module docs for naming scheme.
pub fn build_global(spec: &JobSpec, cost: &dyn CostProvider) -> GlobalDfg {
    build_global_opts(spec, cost, true)
}

/// §Perf: the optimizer's search replays thousands of freshly-built graphs
/// whose node *names* are never read (durations come from the cost model,
/// not a trace join). `with_names = false` skips ~1 string allocation per
/// node — the dominant cost of construction at 128-GPU scale.
pub fn build_global_nameless(spec: &JobSpec, cost: &dyn CostProvider) -> GlobalDfg {
    build_global_opts(spec, cost, false)
}

fn build_global_opts(spec: &JobSpec, cost: &dyn CostProvider, with_names: bool) -> GlobalDfg {
    BUILD_COUNT.with(|c| c.set(c.get() + 1));
    let cluster = &spec.cluster;
    let model = &spec.model;
    let n_workers = cluster.n_workers;
    let mut dfg = Dfg::new();
    let mut comp_node: HashMap<(u16, u32), NodeId> = HashMap::new();
    let mut group_nodes: Vec<Vec<NodeId>> = vec![Vec::new(); spec.plan.groups.len()];
    let mut group_out: HashMap<(u16, usize), Vec<NodeId>> = HashMap::new();
    let mut update_node: HashMap<(u16, usize), NodeId> = HashMap::new();

    macro_rules! name {
        ($($arg:tt)*) => {
            if with_names {
                crate::util::intern::intern(&format!($($arg)*))
            } else {
                crate::util::intern::OpId::EMPTY
            }
        };
    }

    // ---- local DFGs: per-worker computation ops (one node per fusion
    // group; the default singleton plan gives one node per template op) ----
    let fusion = &spec.fusion;
    for w in 0..n_workers as u16 {
        for (gi, members) in fusion.groups.iter().enumerate() {
            let first = &model.ops[members[0] as usize];
            let name = if !with_names {
                crate::util::intern::OpId::EMPTY
            } else if members.len() == 1 {
                crate::util::intern::intern(&format!("w{w}.{}", first.name))
            } else {
                crate::util::intern::intern(&format!(
                    "w{w}.FUSED.{}x{}",
                    members.iter().min().unwrap(),
                    members.len()
                ))
            };
            let id = dfg.add(Node {
                name,
                kind: first.kind,
                device: DeviceKey::Gpu(w),
                duration: cost.comp(w as usize, gi as u32),
                owner: w,
                proc: w,
                tensor: None,
                txid: None,
                template_id: Some(gi as u32),
            });
            comp_node.insert((w, gi as u32), id);
        }
        // edges between groups (dedup via Dfg::edge)
        for (gi, members) in fusion.groups.iter().enumerate() {
            for &m in members {
                for &d in &model.ops[m as usize].deps {
                    let dg = fusion.group_of[d as usize];
                    if dg as usize != gi {
                        dfg.edge(comp_node[&(w, dg)], comp_node[&(w, gi as u32)]);
                    }
                }
            }
        }
    }

    // ---- communication topology per tensor group ----
    let mut txid: u64 = 1;
    for (gi, group) in spec.plan.groups.iter().enumerate() {
        let gbytes = spec.plan.group_bytes(model, gi);
        let producers: Vec<u32> = group
            .tensors
            .iter()
            .filter_map(|&t| model.producer_of(t))
            .map(|op| spec.fusion.group_of[op as usize])
            .collect();

        // In virtual op per worker: all producers of the group's tensors.
        let mut in_ops: Vec<NodeId> = Vec::with_capacity(n_workers);
        for w in 0..n_workers as u16 {
            let id = dfg.add(Node {
                tensor: Some(TensorMeta { tensor_id: gi as u32, bytes: gbytes }),
                ..Node::virtual_op(name!("w{w}.IN.g{gi}"), OpKind::In, w)
            });
            for &p in &producers {
                dfg.edge(comp_node[&(w, p)], id);
            }
            in_ops.push(id);
            group_nodes[gi].push(id);
        }

        let mut out_per_worker: Vec<Vec<NodeId>> = vec![Vec::new(); n_workers];
        build_group_comm(
            &mut dfg, spec, cost, with_names, gi, &in_ops,
            &mut out_per_worker, &mut group_nodes[gi], &mut txid,
        );

        // Out virtual op + update per worker
        for w in 0..n_workers as u16 {
            let out = dfg.add(Node {
                tensor: Some(TensorMeta { tensor_id: gi as u32, bytes: gbytes }),
                ..Node::virtual_op(name!("w{w}.OUT.g{gi}"), OpKind::Out, w)
            });
            for &o in &out_per_worker[w as usize] {
                dfg.edge(o, out);
            }
            group_nodes[gi].push(out);
            group_out.entry((w, gi)).or_default().push(out);

            let upd = dfg.add(Node {
                name: name!("w{w}.UPD.g{gi}"),
                kind: OpKind::Update,
                device: DeviceKey::Gpu(w),
                duration: cost.update(gbytes),
                owner: w,
                proc: w,
                tensor: Some(TensorMeta { tensor_id: gi as u32, bytes: gbytes }),
                txid: None,
                template_id: None,
            });
            dfg.edge(out, upd);
            update_node.insert((w, gi), upd);
        }
    }

    debug_assert!(dfg.is_dag());
    GlobalDfg { dfg, comp_node, group_nodes, group_out, update_node, n_workers }
}

// The per-group communication topology is planned and lowered by
// `graph::comm_plan` (one `CommPlanner` per scheme, one generic lowering
// shared with `graph::mutable`'s in-place splice). Nothing below this line
// knows which scheme is running.

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CommPlan, JobSpec, Transport};
    use crate::models;

    fn small_job(scheme: &str) -> JobSpec {
        let model = models::by_name("vgg16", 8).unwrap();
        let mut spec = JobSpec::standard("vgg16", scheme, Transport::Rdma);
        spec.model = model;
        spec.plan = CommPlan::per_tensor(&spec.model);
        spec
    }

    #[test]
    fn allreduce_dfg_is_dag_with_expected_ops() {
        let spec = small_job("horovod");
        let g = build_global(&spec, &AnalyticCost::new(&spec));
        assert!(g.dfg.is_dag());
        let n_tensors = spec.model.tensors.len();
        // negotiation per group
        let negs = g.dfg.nodes.iter().filter(|n| n.kind == OpKind::Negotiate).count();
        assert_eq!(negs, n_tensors);
        // flat-ring steps 2(N-1)=30, one send per machine per step
        let sends = g.dfg.nodes.iter().filter(|n| n.kind == OpKind::Send).count();
        assert_eq!(sends, n_tensors * 30 * 2);
        // every send has a matching recv with the same txid
        for n in g.dfg.nodes.iter().filter(|n| n.kind == OpKind::Send) {
            let tid = n.txid.unwrap();
            assert!(g
                .dfg
                .nodes
                .iter()
                .any(|m| m.kind == OpKind::Recv && m.txid == Some(tid)));
        }
    }

    #[test]
    fn ps_dfg_pull_waits_for_all_pushes() {
        let spec = small_job("byteps");
        let g = build_global(&spec, &AnalyticCost::new(&spec));
        assert!(g.dfg.is_dag());
        // first pull_send of group 0 must have n_workers aggregate preds
        let pull = g.dfg.find("s0.PULL_SEND.g0.p0.w0").unwrap();
        let agg_preds = g
            .dfg
            .preds(pull)
            .iter()
            .filter(|&&p| g.dfg.node(p).kind == OpKind::Aggregate)
            .count();
        assert_eq!(agg_preds, spec.cluster.n_workers);
    }

    #[test]
    fn partitioned_group_has_k_chains() {
        let mut spec = small_job("byteps");
        spec.plan.groups[0].partitions = 4;
        let g = build_global(&spec, &AnalyticCost::new(&spec));
        let pushes = g
            .dfg
            .nodes
            .iter()
            .filter(|n| n.name.resolve().starts_with("w0.PUSH_SEND.g0."))
            .count();
        assert_eq!(pushes, 4);
        assert!(g.dfg.is_dag());
    }

    #[test]
    fn fused_group_in_depends_on_both_producers() {
        let mut spec = small_job("horovod");
        // fuse tensors 0 and 1 into one group
        let t0 = spec.plan.groups.remove(0);
        spec.plan.groups[0].tensors.splice(0..0, t0.tensors);
        assert_eq!(spec.plan.validate(&spec.model), Ok(()));
        let g = build_global(&spec, &AnalyticCost::new(&spec));
        let in0 = g.dfg.find("w0.IN.g0").unwrap();
        assert!(g.dfg.preds(in0).len() >= 1);
        assert!(g.dfg.is_dag());
    }

    #[test]
    fn update_depends_on_out() {
        let spec = small_job("horovod");
        let g = build_global(&spec, &AnalyticCost::new(&spec));
        let upd = g.update_node[&(0u16, 0usize)];
        let preds = g.dfg.preds(upd);
        assert_eq!(preds.len(), 1);
        assert_eq!(g.dfg.node(preds[0]).kind, OpKind::Out);
    }

    #[test]
    fn single_machine_has_no_ring() {
        let model = models::by_name("vgg16", 8).unwrap();
        let cluster = crate::config::ClusterSpec::new(8, 8, crate::config::NetworkSpec::rdma_100g());
        let spec = JobSpec::with_scheme_name(model, cluster, "horovod");
        let g = build_global(&spec, &AnalyticCost::new(&spec));
        let sends = g.dfg.nodes.iter().filter(|n| n.kind == OpKind::Send).count();
        assert_eq!(sends, 0);
        assert!(g.dfg.is_dag());
    }

    #[test]
    fn ps_server_count_from_cluster() {
        let spec = small_job("byteps");
        assert!(spec.scheme.uses_servers());
        assert_eq!(spec.scheme.n_servers(), 2);
    }

    #[test]
    fn ring_dfg_has_flat_worker_ring() {
        // 8 workers on 2 machines of 4: 2(8-1)=14 steps × 8 workers sends
        // per group, machine-boundary hops on the NIC, the rest on NVLink
        let model = models::by_name("vgg16", 8).unwrap();
        let cluster =
            crate::config::ClusterSpec::new(8, 4, crate::config::NetworkSpec::rdma_100g());
        let spec = JobSpec::with_scheme_name(model, cluster, "ring");
        let g = build_global(&spec, &AnalyticCost::new(&spec));
        assert!(g.dfg.is_dag());
        let n_tensors = spec.model.tensors.len();
        let sends: Vec<_> =
            g.dfg.nodes.iter().filter(|n| n.kind == OpKind::Send).collect();
        assert_eq!(sends.len(), n_tensors * 14 * 8);
        let nic = sends
            .iter()
            .filter(|n| matches!(n.device, DeviceKey::LinkTx(_)))
            .count();
        assert_eq!(nic, n_tensors * 14 * 2, "2 machine-boundary hops per step");
        // every send has a matching recv with the same txid
        let g0_send = g.dfg.find("w0.RSEND.g0.p0.s0").unwrap();
        let tid = g.dfg.node(g0_send).txid.unwrap();
        assert!(g
            .dfg
            .nodes
            .iter()
            .any(|m| m.kind == OpKind::Recv && m.txid == Some(tid)));
    }

    #[test]
    fn ps_tree_dfg_aggregates_per_machine() {
        let spec = small_job("ps-tree");
        let g = build_global(&spec, &AnalyticCost::new(&spec));
        assert!(g.dfg.is_dag());
        let m_count = spec.cluster.n_machines();
        let n_tensors = spec.model.tensors.len();
        // server ingress is one aggregate per *machine* per group
        let aggs = g
            .dfg
            .nodes
            .iter()
            .filter(|n| matches!(n.device, DeviceKey::PsCpu(_)))
            .count();
        assert_eq!(aggs, n_tensors * m_count);
        // the pull of group 0 waits on every machine's contribution
        let pull = g.dfg.find("s0.TPULL_SEND.g0.p0.m0").unwrap();
        let agg_preds = g
            .dfg
            .preds(pull)
            .iter()
            .filter(|&&p| g.dfg.node(p).kind == OpKind::Aggregate)
            .count();
        assert_eq!(agg_preds, m_count);
        // every worker's Out op is fed (an H2D tail exists per worker)
        for w in 0..spec.cluster.n_workers {
            assert!(g.dfg.find(&format!("w{w}.H2D.g0.p0")).is_some());
        }
    }

    #[test]
    fn all_schemes_build_replayable_dfgs() {
        for scheme in crate::config::ALL_SCHEMES {
            let spec = small_job(scheme);
            let g = build_global(&spec, &AnalyticCost::new(&spec));
            assert!(g.dfg.is_dag(), "{scheme}");
            let r = crate::replay::replay_once(&g);
            assert!(
                r.iteration_time.is_finite() && r.iteration_time > 0.0,
                "{scheme}: iteration {}",
                r.iteration_time
            );
            // update ops exist and run after their group's Out
            let upd = g.update_node[&(0u16, 0usize)];
            assert!(r.end[upd as usize] > 0.0, "{scheme}");
        }
    }
}
