//! Global-DFG construction (paper §4.1): connect per-worker local DFGs with
//! the fine-grained communication topology of the chosen synchronization
//! scheme, via In/Out virtual ops and producer/consumer (SEND/RECV) pairs
//! labelled with transaction ids.
//!
//! Op names are deterministic and shared with the testbed's trace emitter,
//! so measured traces can be joined back onto the skeleton by name.

use std::collections::HashMap;

use crate::config::{ClusterSpec, CommScheme, JobSpec};
use crate::graph::dfg::{DeviceKey, Dfg, Node, NodeId, OpKind, TensorMeta};
use crate::util::Us;

thread_local! {
    /// Count of full global-DFG constructions (named and nameless) on this
    /// thread. The optimizer's hot loop must perform none after its setup
    /// phase — the incremental subsystem ([`crate::graph::mutable`]) edits
    /// the graph in place instead — and tests assert that through this
    /// counter. Thread-local so concurrently running tests cannot pollute
    /// each other's deltas.
    static BUILD_COUNT: std::cell::Cell<usize> = std::cell::Cell::new(0);
}

/// Monotonic number of global-DFG constructions so far on this thread.
pub fn build_count() -> usize {
    BUILD_COUNT.with(|c| c.get())
}

/// Supplies op durations during construction. `AnalyticCost` derives them
/// from the cluster spec; the profiler swaps in measured averages.
pub trait CostProvider {
    /// Duration of *fusion group* `group_id` on `worker` (singleton groups
    /// are plain template ops).
    fn comp(&self, worker: usize, group_id: u32) -> Us;
    /// TX-side occupancy of sending `bytes` (one message).
    fn send(&self, bytes: f64, intra_machine: bool) -> Us;
    /// RX-side occupancy of receiving `bytes` (one message).
    fn recv(&self, bytes: f64, intra_machine: bool) -> Us;
    /// Coordinator negotiation delay for one tensor group (AllReduce).
    fn negotiate(&self) -> Us;
    /// NVLink reduce of `bytes` across the GPUs of one machine.
    fn reduce_local(&self, bytes: f64, n_gpus: usize) -> Us;
    /// NVLink broadcast of `bytes` to the GPUs of one machine.
    fn bcast_local(&self, bytes: f64, n_gpus: usize) -> Us;
    /// PS server-side aggregation of one pushed partition.
    fn aggregate(&self, bytes: f64) -> Us;
    /// Optimizer update for `bytes` of parameters on a worker.
    fn update(&self, bytes: f64) -> Us;
    /// GPU-side kernel time a collective/copy costs *on the worker's GPU*
    /// (NCCL reduce-scatter/all-gather kernels, D2H/H2D staging): the
    /// compute/communication contention term Daydream does not model.
    fn gpu_collective(&self, bytes: f64) -> Us;
}

/// Cost model implied by the job spec (no noise — expectation values).
pub struct AnalyticCost<'a> {
    pub spec: &'a JobSpec,
}

impl<'a> AnalyticCost<'a> {
    pub fn new(spec: &'a JobSpec) -> Self {
        AnalyticCost { spec }
    }
}

impl CostProvider for AnalyticCost<'_> {
    fn comp(&self, _worker: usize, group_id: u32) -> Us {
        self.spec.fusion.duration(&self.spec.model, &self.spec.cluster.gpu, group_id as usize)
    }

    fn send(&self, bytes: f64, intra: bool) -> Us {
        let net = &self.spec.cluster.network;
        if intra {
            net.nvlink_time_us(bytes)
        } else {
            net.per_msg_overhead_us() + net.wire_time_us(bytes)
        }
    }

    fn recv(&self, bytes: f64, intra: bool) -> Us {
        let net = &self.spec.cluster.network;
        if intra {
            net.nvlink_time_us(bytes)
        } else {
            net.base_latency_us() + net.wire_time_us(bytes)
        }
    }

    fn negotiate(&self) -> Us {
        match &self.spec.scheme {
            CommScheme::AllReduce(ar) => ar.cycle_time_us * 0.5,
            CommScheme::Ps(_) => 0.0,
        }
    }

    fn reduce_local(&self, bytes: f64, n_gpus: usize) -> Us {
        if n_gpus <= 1 {
            return 0.0;
        }
        // ring-reduce within the machine over NVLink
        self.spec.cluster.network.nvlink_time_us(bytes) * 2.0 * (n_gpus - 1) as f64
            / n_gpus as f64
    }

    fn bcast_local(&self, bytes: f64, n_gpus: usize) -> Us {
        if n_gpus <= 1 {
            return 0.0;
        }
        self.spec.cluster.network.nvlink_time_us(bytes)
    }

    fn aggregate(&self, bytes: f64) -> Us {
        match &self.spec.scheme {
            CommScheme::Ps(ps) => bytes / ps.agg_bytes_per_s * 1e6,
            CommScheme::AllReduce(_) => 0.0,
        }
    }

    fn update(&self, bytes: f64) -> Us {
        // SGD+momentum: ~4 passes over the parameter bytes, memory-bound.
        let gpu = &self.spec.cluster.gpu;
        gpu.launch_overhead_us + 4.0 * bytes / gpu.mem_bw * 1e6
    }

    fn gpu_collective(&self, bytes: f64) -> Us {
        // kernel launch + stream sync (~90 us) + reduction/copy at ~40 GB/s
        90.0 + bytes / 40.0e9 * 1e6
    }
}

/// The constructed global DFG plus lookup tables used by replay, partial
/// replay and the optimizer.
#[derive(Clone, Debug)]
pub struct GlobalDfg {
    pub dfg: Dfg,
    /// comp node of (worker, fusion-group id); with the default singleton
    /// fusion plan, group id == template op id
    pub comp_node: HashMap<(u16, u32), NodeId>,
    /// all communication-chain node ids of each tensor group (for partial
    /// replay of a tensor's synchronization, paper §5.3)
    pub group_nodes: Vec<Vec<NodeId>>,
    /// Out virtual ops per (worker, group)
    pub group_out: HashMap<(u16, usize), Vec<NodeId>>,
    /// update node per (worker, group)
    pub update_node: HashMap<(u16, usize), NodeId>,
    pub n_workers: usize,
}

/// Build the global DFG for a job. See module docs for naming scheme.
pub fn build_global(spec: &JobSpec, cost: &dyn CostProvider) -> GlobalDfg {
    build_global_opts(spec, cost, true)
}

/// §Perf: the optimizer's search replays thousands of freshly-built graphs
/// whose node *names* are never read (durations come from the cost model,
/// not a trace join). `with_names = false` skips ~1 string allocation per
/// node — the dominant cost of construction at 128-GPU scale.
pub fn build_global_nameless(spec: &JobSpec, cost: &dyn CostProvider) -> GlobalDfg {
    build_global_opts(spec, cost, false)
}

fn build_global_opts(spec: &JobSpec, cost: &dyn CostProvider, with_names: bool) -> GlobalDfg {
    BUILD_COUNT.with(|c| c.set(c.get() + 1));
    let cluster = &spec.cluster;
    let model = &spec.model;
    let n_workers = cluster.n_workers;
    let mut dfg = Dfg::new();
    let mut comp_node: HashMap<(u16, u32), NodeId> = HashMap::new();
    let mut group_nodes: Vec<Vec<NodeId>> = vec![Vec::new(); spec.plan.groups.len()];
    let mut group_out: HashMap<(u16, usize), Vec<NodeId>> = HashMap::new();
    let mut update_node: HashMap<(u16, usize), NodeId> = HashMap::new();

    macro_rules! name {
        ($($arg:tt)*) => {
            if with_names { format!($($arg)*) } else { String::new() }
        };
    }

    // ---- local DFGs: per-worker computation ops (one node per fusion
    // group; the default singleton plan gives one node per template op) ----
    let fusion = &spec.fusion;
    for w in 0..n_workers as u16 {
        for (gi, members) in fusion.groups.iter().enumerate() {
            let first = &model.ops[members[0] as usize];
            let name = if !with_names {
                String::new()
            } else if members.len() == 1 {
                format!("w{w}.{}", first.name)
            } else {
                format!("w{w}.FUSED.{}x{}", members.iter().min().unwrap(), members.len())
            };
            let id = dfg.add(Node {
                name,
                kind: first.kind,
                device: DeviceKey::Gpu(w),
                duration: cost.comp(w as usize, gi as u32),
                owner: w,
                proc: w,
                tensor: None,
                txid: None,
                template_id: Some(gi as u32),
            });
            comp_node.insert((w, gi as u32), id);
        }
        // edges between groups (dedup via Dfg::edge)
        for (gi, members) in fusion.groups.iter().enumerate() {
            for &m in members {
                for &d in &model.ops[m as usize].deps {
                    let dg = fusion.group_of[d as usize];
                    if dg as usize != gi {
                        dfg.edge(comp_node[&(w, dg)], comp_node[&(w, gi as u32)]);
                    }
                }
            }
        }
    }

    // ---- communication topology per tensor group ----
    let mut txid: u64 = 1;
    for (gi, group) in spec.plan.groups.iter().enumerate() {
        let gbytes = spec.plan.group_bytes(model, gi);
        let producers: Vec<u32> = group
            .tensors
            .iter()
            .filter_map(|&t| model.producer_of(t))
            .map(|op| spec.fusion.group_of[op as usize])
            .collect();

        // In virtual op per worker: all producers of the group's tensors.
        let mut in_ops: Vec<NodeId> = Vec::with_capacity(n_workers);
        for w in 0..n_workers as u16 {
            let id = dfg.add(Node {
                tensor: Some(TensorMeta { tensor_id: gi as u32, bytes: gbytes }),
                ..Node::virtual_op(name!("w{w}.IN.g{gi}"), OpKind::In, w)
            });
            for &p in &producers {
                dfg.edge(comp_node[&(w, p)], id);
            }
            in_ops.push(id);
            group_nodes[gi].push(id);
        }

        let mut out_per_worker: Vec<Vec<NodeId>> = vec![Vec::new(); n_workers];
        build_group_comm(
            &mut dfg, spec, cost, with_names, gi, &in_ops,
            &mut out_per_worker, &mut group_nodes[gi], &mut txid,
        );

        // Out virtual op + update per worker
        for w in 0..n_workers as u16 {
            let out = dfg.add(Node {
                tensor: Some(TensorMeta { tensor_id: gi as u32, bytes: gbytes }),
                ..Node::virtual_op(name!("w{w}.OUT.g{gi}"), OpKind::Out, w)
            });
            for &o in &out_per_worker[w as usize] {
                dfg.edge(o, out);
            }
            group_nodes[gi].push(out);
            group_out.entry((w, gi)).or_default().push(out);

            let upd = dfg.add(Node {
                name: name!("w{w}.UPD.g{gi}"),
                kind: OpKind::Update,
                device: DeviceKey::Gpu(w),
                duration: cost.update(gbytes),
                owner: w,
                proc: w,
                tensor: Some(TensorMeta { tensor_id: gi as u32, bytes: gbytes }),
                txid: None,
                template_id: None,
            });
            dfg.edge(out, upd);
            update_node.insert((w, gi), upd);
        }
    }

    debug_assert!(dfg.is_dag());
    GlobalDfg { dfg, comp_node, group_nodes, group_out, update_node, n_workers }
}

/// Build the communication topology of one tensor group — the negotiation
/// op (AllReduce) plus the per-partition chains — appending to `dfg` and
/// wiring from the group's In ops. `out_per_worker` collects the chain
/// tails that feed each worker's Out op; `gnodes` records every created
/// node in canonical creation order. Shared by the full builder above and
/// by the in-place comm-chain splice of [`crate::graph::mutable`], so an
/// incrementally rewritten group is structurally identical to a fresh
/// build of the same spec.
#[allow(clippy::too_many_arguments)]
pub(crate) fn build_group_comm(
    dfg: &mut Dfg,
    spec: &JobSpec,
    cost: &dyn CostProvider,
    with_names: bool,
    gi: usize,
    in_ops: &[NodeId],
    out_per_worker: &mut [Vec<NodeId>],
    gnodes: &mut Vec<NodeId>,
    txid: &mut u64,
) {
    let cluster = &spec.cluster;
    let gbytes = spec.plan.group_bytes(&spec.model, gi);
    let group = &spec.plan.groups[gi];
    let k = group.partitions.max(1);
    let pbytes = gbytes / k as f64;
    macro_rules! name {
        ($($arg:tt)*) => {
            if with_names { format!($($arg)*) } else { String::new() }
        };
    }
    match &spec.scheme {
        CommScheme::AllReduce(_) => {
            // negotiation op: coordinator serializes group scheduling
            let neg = dfg.add(Node {
                name: name!("neg.g{gi}"),
                kind: OpKind::Negotiate,
                // a delay, not an exclusive resource: Null device means
                // "elapses without queuing" in testbed and replayer
                device: DeviceKey::Null,
                duration: cost.negotiate(),
                owner: 0,
                proc: crate::graph::dfg::COORD_PROC,
                tensor: Some(TensorMeta { tensor_id: gi as u32, bytes: gbytes }),
                txid: None,
                template_id: None,
            });
            for &i in in_ops {
                dfg.edge(i, neg);
            }
            gnodes.push(neg);
            for p in 0..k {
                build_allreduce_partition(
                    dfg, cluster, cost, with_names, gi, p, pbytes, neg,
                    out_per_worker, gnodes, txid,
                );
            }
        }
        CommScheme::Ps(ps) => {
            for p in 0..k {
                // Server assignment is keyed by the group's first tensor
                // id, not its plan index: tensor ids are stable under
                // tensor fusion, so an in-place chain splice and a fresh
                // rebuild agree on placement even after earlier groups
                // were merged away (plan indices shift, tensor ids never
                // do).
                let server = (group.tensors[0] as usize + p) % ps.n_servers;
                build_ps_partition(
                    dfg, cluster, cost, with_names, gi, p, pbytes, server, in_ops,
                    out_per_worker, gnodes, txid,
                );
            }
        }
    }
}

/// AllReduce for one partition, modeled as NCCL models it: NVLink reduce
/// within each machine, then a flat-ring equivalent across machine NICs —
/// `2(N−1)` pipelined chunk steps of `bytes/N` each, so every NIC crossing
/// carries the full `2(N−1)/N × bytes` ring volume with per-chunk latency
/// — and an NVLink broadcast back to local GPUs.
#[allow(clippy::too_many_arguments)]
fn build_allreduce_partition(
    dfg: &mut Dfg,
    cluster: &ClusterSpec,
    cost: &dyn CostProvider,
    with_names: bool,
    gi: usize,
    p: usize,
    pbytes: f64,
    neg: NodeId,
    out_per_worker: &mut [Vec<NodeId>],
    gnodes: &mut Vec<NodeId>,
    txid: &mut u64,
) {
    let m_count = cluster.n_machines();
    let meta = |bytes: f64| Some(TensorMeta { tensor_id: gi as u32, bytes });
    macro_rules! name {
        ($($arg:tt)*) => {
            if with_names { format!($($arg)*) } else { String::new() }
        };
    }

    // per-worker GPU reduce-scatter kernel, then NVLink reduce per machine
    let mut reduced: Vec<NodeId> = Vec::with_capacity(m_count);
    for m in 0..m_count {
        let gpus = cluster.workers_on(m);
        let mut rs_ops = Vec::with_capacity(gpus.len());
        for &w in &gpus {
            let rs = dfg.add(Node {
                name: name!("w{w}.NCCL_RS.g{gi}.p{p}"),
                kind: OpKind::Aggregate,
                device: DeviceKey::Gpu(w as u16),
                duration: cost.gpu_collective(pbytes),
                owner: w as u16,
                proc: w as u16,
                tensor: meta(pbytes),
                txid: None,
                template_id: None,
            });
            dfg.edge(neg, rs);
            rs_ops.push(rs);
            gnodes.push(rs);
        }
        let id = dfg.add(Node {
            name: name!("m{m}.RED.g{gi}.p{p}"),
            kind: OpKind::Aggregate,
            device: DeviceKey::NvLink(m as u16),
            duration: cost.reduce_local(pbytes, gpus.len()),
            owner: gpus[0] as u16,
            proc: gpus[0] as u16,
            tensor: meta(pbytes),
            txid: None,
            template_id: None,
        });
        for &rs in &rs_ops {
            dfg.edge(rs, id);
        }
        reduced.push(id);
        gnodes.push(id);
    }

    // ring across machines: 2(N-1) flat-ring chunk steps of bytes/N
    let mut last_recv: Vec<NodeId> = reduced.clone();
    if m_count > 1 {
        let n = cluster.n_workers;
        let chunk = pbytes / n as f64;
        let steps = 2 * (n - 1);
        let mut prev_send: Vec<Option<NodeId>> = vec![None; m_count];
        for step in 0..steps {
            let mut this_recv: Vec<NodeId> = vec![0; m_count];
            for m in 0..m_count {
                let dst = (m + 1) % m_count;
                let tid = *txid;
                *txid += 1;
                let send = dfg.add(Node {
                    name: name!("m{m}.SEND.g{gi}.p{p}.s{step}"),
                    kind: OpKind::Send,
                    device: DeviceKey::LinkTx(m as u16),
                    duration: cost.send(chunk, false),
                    owner: cluster.workers_on(m)[0] as u16,
                    proc: cluster.workers_on(m)[0] as u16,
                    tensor: meta(chunk),
                    txid: Some(tid),
                    template_id: None,
                });
                // forward what we received last step (or the local reduction)
                dfg.edge(last_recv[m], send);
                if let Some(ps) = prev_send[m] {
                    dfg.edge(ps, send);
                }
                let recv = dfg.add(Node {
                    name: name!("m{dst}.RECV.g{gi}.p{p}.s{step}"),
                    kind: OpKind::Recv,
                    device: DeviceKey::LinkRx(dst as u16),
                    duration: cost.recv(chunk, false),
                    owner: cluster.workers_on(dst)[0] as u16,
                    proc: cluster.workers_on(dst)[0] as u16,
                    tensor: meta(chunk),
                    txid: Some(tid),
                    template_id: None,
                });
                dfg.edge(send, recv);
                this_recv[dst] = recv;
                prev_send[m] = Some(send);
                gnodes.push(send);
                gnodes.push(recv);
            }
            last_recv = this_recv;
        }
    }

    // local broadcast + per-worker GPU all-gather kernel feeding Out
    for m in 0..m_count {
        let gpus = cluster.workers_on(m);
        let bc = dfg.add(Node {
            name: name!("m{m}.BCAST.g{gi}.p{p}"),
            kind: OpKind::Aggregate,
            device: DeviceKey::NvLink(m as u16),
            duration: cost.bcast_local(pbytes, gpus.len()),
            owner: gpus[0] as u16,
            proc: gpus[0] as u16,
            tensor: meta(pbytes),
            txid: None,
            template_id: None,
        });
        dfg.edge(last_recv[m], bc);
        gnodes.push(bc);
        for w in gpus {
            let ag = dfg.add(Node {
                name: name!("w{w}.NCCL_AG.g{gi}.p{p}"),
                kind: OpKind::Aggregate,
                device: DeviceKey::Gpu(w as u16),
                duration: cost.gpu_collective(pbytes),
                owner: w as u16,
                proc: w as u16,
                tensor: meta(pbytes),
                txid: None,
                template_id: None,
            });
            dfg.edge(bc, ag);
            gnodes.push(ag);
            out_per_worker[w].push(ag);
        }
    }
}

/// PS PUSH/PULL for one partition on its assigned server: each worker
/// pushes (SEND→RECV), the server aggregates each contribution, and once
/// all contributions are in, each worker pulls (SEND→RECV).
#[allow(clippy::too_many_arguments)]
fn build_ps_partition(
    dfg: &mut Dfg,
    cluster: &ClusterSpec,
    cost: &dyn CostProvider,
    with_names: bool,
    gi: usize,
    p: usize,
    pbytes: f64,
    server: usize,
    in_ops: &[NodeId],
    out_per_worker: &mut [Vec<NodeId>],
    gnodes: &mut Vec<NodeId>,
    txid: &mut u64,
) {
    let n_workers = cluster.n_workers;
    let meta = Some(TensorMeta { tensor_id: gi as u32, bytes: pbytes });
    macro_rules! name {
        ($($arg:tt)*) => {
            if with_names { format!($($arg)*) } else { String::new() }
        };
    }
    // PS `server` runs on machine `server` (colocated mode).
    let server_machine = server % cluster.n_machines().max(1);
    let mut aggs: Vec<NodeId> = Vec::with_capacity(n_workers);

    for w in 0..n_workers {
        let wm = cluster.machine_of(w);
        let intra = wm == server_machine;
        let tid = *txid;
        *txid += 1;
        let d2h = dfg.add(Node {
            name: name!("w{w}.D2H.g{gi}.p{p}"),
            kind: OpKind::Aggregate,
            device: DeviceKey::Gpu(w as u16),
            duration: cost.gpu_collective(pbytes),
            owner: w as u16,
            proc: w as u16,
            tensor: meta,
            txid: None,
            template_id: None,
        });
        dfg.edge(in_ops[w], d2h);
        gnodes.push(d2h);
        let push_send = dfg.add(Node {
            name: name!("w{w}.PUSH_SEND.g{gi}.p{p}"),
            kind: OpKind::Send,
            device: if intra { DeviceKey::NvLink(wm as u16) } else { DeviceKey::LinkTx(wm as u16) },
            duration: cost.send(pbytes, intra),
            owner: w as u16,
            proc: w as u16,
            tensor: meta,
            txid: Some(tid),
            template_id: None,
        });
        dfg.edge(d2h, push_send);
        let push_recv = dfg.add(Node {
            name: name!("s{server}.PUSH_RECV.g{gi}.p{p}.w{w}"),
            kind: OpKind::Recv,
            device: if intra {
                DeviceKey::NvLink(server_machine as u16)
            } else {
                DeviceKey::LinkRx(server_machine as u16)
            },
            duration: if intra { 0.0 } else { cost.recv(pbytes, false) },
            owner: w as u16,
            proc: (cluster.n_workers + server) as u16,
            tensor: meta,
            txid: Some(tid),
            template_id: None,
        });
        dfg.edge(push_send, push_recv);
        let agg = dfg.add(Node {
            name: name!("s{server}.AGG.g{gi}.p{p}.w{w}"),
            kind: OpKind::Aggregate,
            device: DeviceKey::PsCpu(server as u16),
            duration: cost.aggregate(pbytes),
            owner: w as u16,
            proc: (cluster.n_workers + server) as u16,
            tensor: meta,
            txid: None,
            template_id: None,
        });
        dfg.edge(push_recv, agg);
        aggs.push(agg);
        gnodes.extend_from_slice(&[push_send, push_recv, agg]);
    }

    for w in 0..n_workers {
        let wm = cluster.machine_of(w);
        let intra = wm == server_machine;
        let tid = *txid;
        *txid += 1;
        let pull_send = dfg.add(Node {
            name: name!("s{server}.PULL_SEND.g{gi}.p{p}.w{w}"),
            kind: OpKind::Send,
            device: if intra {
                DeviceKey::NvLink(server_machine as u16)
            } else {
                DeviceKey::LinkTx(server_machine as u16)
            },
            duration: cost.send(pbytes, intra),
            owner: w as u16,
            proc: w as u16,
            tensor: meta,
            txid: Some(tid),
            template_id: None,
        });
        // synchronous training: pull waits for every worker's contribution
        for &a in &aggs {
            dfg.edge(a, pull_send);
        }
        let pull_recv = dfg.add(Node {
            name: name!("w{w}.PULL_RECV.g{gi}.p{p}"),
            kind: OpKind::Recv,
            device: if intra { DeviceKey::NvLink(wm as u16) } else { DeviceKey::LinkRx(wm as u16) },
            duration: if intra { 0.0 } else { cost.recv(pbytes, false) },
            owner: w as u16,
            proc: w as u16,
            tensor: meta,
            txid: Some(tid),
            template_id: None,
        });
        dfg.edge(pull_send, pull_recv);
        let h2d = dfg.add(Node {
            name: name!("w{w}.H2D.g{gi}.p{p}"),
            kind: OpKind::Aggregate,
            device: DeviceKey::Gpu(w as u16),
            duration: cost.gpu_collective(pbytes),
            owner: w as u16,
            proc: w as u16,
            tensor: meta,
            txid: None,
            template_id: None,
        });
        dfg.edge(pull_recv, h2d);
        out_per_worker[w].push(h2d);
        gnodes.extend_from_slice(&[pull_send, pull_recv, h2d]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArSpec, CommPlan, JobSpec, PsSpec, Transport};
    use crate::models;

    fn small_job(scheme: &str) -> JobSpec {
        let model = models::by_name("vgg16", 8).unwrap();
        let mut spec = JobSpec::standard("vgg16", scheme, Transport::Rdma);
        spec.model = model;
        spec.plan = CommPlan::per_tensor(&spec.model);
        spec
    }

    #[test]
    fn allreduce_dfg_is_dag_with_expected_ops() {
        let spec = small_job("horovod");
        let g = build_global(&spec, &AnalyticCost::new(&spec));
        assert!(g.dfg.is_dag());
        let n_tensors = spec.model.tensors.len();
        // negotiation per group
        let negs = g.dfg.nodes.iter().filter(|n| n.kind == OpKind::Negotiate).count();
        assert_eq!(negs, n_tensors);
        // flat-ring steps 2(N-1)=30, one send per machine per step
        let sends = g.dfg.nodes.iter().filter(|n| n.kind == OpKind::Send).count();
        assert_eq!(sends, n_tensors * 30 * 2);
        // every send has a matching recv with the same txid
        for n in g.dfg.nodes.iter().filter(|n| n.kind == OpKind::Send) {
            let tid = n.txid.unwrap();
            assert!(g
                .dfg
                .nodes
                .iter()
                .any(|m| m.kind == OpKind::Recv && m.txid == Some(tid)));
        }
    }

    #[test]
    fn ps_dfg_pull_waits_for_all_pushes() {
        let spec = small_job("byteps");
        let g = build_global(&spec, &AnalyticCost::new(&spec));
        assert!(g.dfg.is_dag());
        // first pull_send of group 0 must have n_workers aggregate preds
        let pull = g.dfg.find("s0.PULL_SEND.g0.p0.w0").unwrap();
        let agg_preds = g
            .dfg
            .preds(pull)
            .iter()
            .filter(|&&p| g.dfg.node(p).kind == OpKind::Aggregate)
            .count();
        assert_eq!(agg_preds, spec.cluster.n_workers);
    }

    #[test]
    fn partitioned_group_has_k_chains() {
        let mut spec = small_job("byteps");
        spec.plan.groups[0].partitions = 4;
        let g = build_global(&spec, &AnalyticCost::new(&spec));
        let pushes = g
            .dfg
            .nodes
            .iter()
            .filter(|n| n.name.starts_with("w0.PUSH_SEND.g0."))
            .count();
        assert_eq!(pushes, 4);
        assert!(g.dfg.is_dag());
    }

    #[test]
    fn fused_group_in_depends_on_both_producers() {
        let mut spec = small_job("horovod");
        // fuse tensors 0 and 1 into one group
        let t0 = spec.plan.groups.remove(0);
        spec.plan.groups[0].tensors.splice(0..0, t0.tensors);
        assert_eq!(spec.plan.validate(&spec.model), Ok(()));
        let g = build_global(&spec, &AnalyticCost::new(&spec));
        let in0 = g.dfg.find("w0.IN.g0").unwrap();
        assert!(g.dfg.preds(in0).len() >= 1);
        assert!(g.dfg.is_dag());
    }

    #[test]
    fn update_depends_on_out() {
        let spec = small_job("horovod");
        let g = build_global(&spec, &AnalyticCost::new(&spec));
        let upd = g.update_node[&(0u16, 0usize)];
        let preds = g.dfg.preds(upd);
        assert_eq!(preds.len(), 1);
        assert_eq!(g.dfg.node(preds[0]).kind, OpKind::Out);
    }

    #[test]
    fn single_machine_has_no_ring() {
        let model = models::by_name("vgg16", 8).unwrap();
        let cluster = crate::config::ClusterSpec::new(8, 8, crate::config::NetworkSpec::rdma_100g());
        let spec = JobSpec::new(model, cluster, crate::config::CommScheme::AllReduce(ArSpec::default()));
        let g = build_global(&spec, &AnalyticCost::new(&spec));
        let sends = g.dfg.nodes.iter().filter(|n| n.kind == OpKind::Send).count();
        assert_eq!(sends, 0);
        assert!(g.dfg.is_dag());
    }

    #[test]
    fn ps_server_count_from_cluster() {
        let spec = small_job("byteps");
        if let crate::config::CommScheme::Ps(ps) = &spec.scheme {
            assert_eq!(ps.n_servers, 2);
        } else {
            panic!("expected PS");
        }
        let _ = PsSpec::for_cluster(&spec.cluster);
    }
}
