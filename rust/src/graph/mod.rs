//! Data-flow-graph layer: arena DFG, the comm-plan IR + per-scheme
//! planners, and global-DFG construction from a job spec (local DFGs ×
//! fine-grained communication topology, §4.1).

pub mod build;
pub mod comm_plan;
pub mod dfg;
pub mod mutable;

pub use build::{
    build_count, build_global, build_global_nameless, AnalyticCost, CostProvider, GlobalDfg,
};
pub use comm_plan::{
    plan_props, plan_symmetry, CommPlanner, Dep, GroupPlan, PlanCtx, PlanProps, PlanSymmetry,
    Stage,
};
pub use dfg::{DeviceKey, Dfg, Node, NodeId, OpKind, TensorId, TensorMeta};
pub use mutable::{ChangeLog, MutableGraph, Txn};
