//! Data-flow-graph layer: arena DFG, and global-DFG construction from a
//! job spec (local DFGs × fine-grained communication topology, §4.1).

pub mod build;
pub mod dfg;

pub use build::{build_global, build_global_nameless, AnalyticCost, CostProvider, GlobalDfg};
pub use dfg::{DeviceKey, Dfg, Node, NodeId, OpKind, TensorId, TensorMeta};
