//! Mutable-plan layer: apply optimizer decisions (op fusion, tensor
//! fusion, tensor partition) as **in-place edits** of an already-built
//! global DFG, instead of round-tripping through `JobSpec` →
//! [`crate::graph::build_global_nameless`] on every search round.
//!
//! The three primitive edits mirror [`crate::optimizer::passes`] (which
//! stays the source of truth for *plan* validity — every edit first goes
//! through the corresponding pass on the owned [`JobSpec`], then replays
//! the same rewrite on the graph):
//!
//! - **op fusion** — per worker, the dropped group's comp node is merged
//!   into the kept one: edges redirected, duration set to the fused-kernel
//!   time, the dropped node tombstoned;
//! - **tensor fusion** — the dropped group's whole synchronization subgraph
//!   (In/chain/Out/update) is tombstoned, the kept group's In ops gain the
//!   merged producers, and the kept chain is re-spliced at the fused size;
//! - **tensor partition** — the group's comm chain is re-spliced with the
//!   new partition count.
//!
//! Chain splices call the exact same [`build_group_comm`] the full builder
//! uses, so an incrementally-edited graph is *structurally identical* (up
//! to node numbering) to a fresh build of the mutated spec — the invariant
//! the `incremental` equivalence tests pin down. Tombstoned nodes stay in
//! the arena (ids are stable) but are detached, zero-duration, and
//! device-less; the incremental replayer skips them via [`Self::alive`].
//!
//! Every edit is logged into a [`ChangeLog`] (tombstoned ids, revived ids,
//! touched ids, append watermark) that
//! [`crate::replay::incremental::IncrementalReplayer`] drains to confine
//! its recomputation to the affected cone.
//!
//! ## Transactions
//!
//! The optimizer's accept/reject loop evaluates every candidate decision by
//! applying it, replaying, and *keeping or discarding* it. Discarding must
//! not rebuild anything, so every primitive edit performed inside an open
//! transaction ([`MutableGraph::begin`]) additionally records its **inverse**
//! in an edit journal: tombstones save the node's fields and adjacency,
//! spec rewrites save the displaced groups (moved, not spec-cloned), chain
//! splices save the appended-node watermark and the displaced index rows.
//! [`MutableGraph::rollback`] replays the journal in reverse, restoring the
//! graph, the spec, and the plan indices bit-for-bit — a rejected candidate
//! costs one cone repair on the next replay and nothing else. Nodes revived
//! by a rollback are reported to the engine through [`ChangeLog::revived`].

use crate::config::JobSpec;
use crate::graph::build::{AnalyticCost, CostProvider};
use crate::graph::comm_plan::build_group_comm;
use crate::graph::dfg::{DeviceKey, Dfg, NodeId, OpKind};
use crate::graph::{build_global_nameless, GlobalDfg};
use crate::models::ModelGraph;
use crate::optimizer::passes::{self, PassError};

/// Canonical rank of a node: a total order shared by incrementally-edited
/// and freshly-built graphs of the same spec, used by the incremental
/// replayer to break exact ties deterministically. Encoded as
/// `class << 60 | major << 32 | minor`:
///
/// - comp ops:   `(0, worker, fusion-group index)`
/// - comm nodes: `(1, comm-group index, creation order within the group)`
/// - update ops: `(2, comm-group index, worker)`
///
/// The rank is *dependency-consistent on every device for simultaneous
/// ops*: within a chain, creation order follows dependencies, and any
/// cross-class dependency passes through an op of positive duration, so
/// equal-time ties can only occur between rank-ordered pairs.
#[inline]
fn canon_rank(class: u64, major: u64, minor: u64) -> u64 {
    debug_assert!(class < 8 && major < (1 << 28) && minor < (1 << 32));
    (class << 60) | (major << 32) | minor
}

/// What changed since the last [`MutableGraph::commit`]: the incremental
/// replayer's repair seeds.
#[derive(Clone, Debug, Default)]
pub struct ChangeLog {
    /// Tombstoned node ids (graph edits never reuse ids).
    pub removed: Vec<NodeId>,
    /// Previously-tombstoned nodes brought back by a transaction rollback;
    /// the engine re-interns their device membership like fresh additions.
    pub revived: Vec<NodeId>,
    /// Surviving nodes whose duration or predecessor set changed.
    pub touched: Vec<NodeId>,
    /// Nodes with id `>= added_from` were appended since the last commit.
    pub added_from: NodeId,
}

impl ChangeLog {
    /// True when the log records no change against a graph of `n_now`
    /// nodes (nothing removed/revived/touched/appended).
    pub fn is_empty(&self, n_now: usize) -> bool {
        self.removed.is_empty()
            && self.revived.is_empty()
            && self.touched.is_empty()
            && self.added_from as usize >= n_now
    }
}

/// Inverse of one primitive mutation, recorded while a transaction is open
/// and replayed (in reverse order) by [`MutableGraph::rollback`].
enum UndoOp {
    /// `plan.groups[g].partitions` was `old`.
    SpecPartitions { g: usize, old: usize },
    /// `passes::fuse_tensor_groups(keep, drop)` displaced these groups.
    SpecTensorFuse {
        keep: usize,
        drop: usize,
        old_kept: crate::config::TensorGroup,
        dropped: crate::config::TensorGroup,
    },
    /// `passes::fuse_comp_groups(keep, drop)` displaced these groups.
    SpecOpFuse { keep: usize, drop: usize, old_kept: Vec<u32>, dropped: Vec<u32> },
    /// [`MutableGraph::swap_model`] displaced this template (moved in, not
    /// cloned — the undo record owns the old model).
    SpecModel { old: ModelGraph },
    /// A dependency edge was newly inserted.
    EdgeAdded { from: NodeId, to: NodeId },
    /// A live node was tombstoned; fields + adjacency as of that moment.
    Tombstoned {
        id: NodeId,
        device: DeviceKey,
        duration: f64,
        template_id: Option<u32>,
        preds: Vec<NodeId>,
        succs: Vec<NodeId>,
    },
    /// A node was appended by a chain splice (undo kills it for good —
    /// ids are never reused).
    Appended { id: NodeId },
    /// A node's duration was overwritten.
    Duration { id: NodeId, old: f64 },
    /// A node's tensor-meta byte count was overwritten.
    TensorBytes { id: NodeId, old: f64 },
    /// `comp[w].remove(drop)` for every worker; `col[w]` is the removed id.
    CompColumn { drop: usize, col: Vec<NodeId> },
    /// The four per-group index rows removed for a dropped comm group.
    GroupIndex {
        drop: usize,
        in_ops: Vec<NodeId>,
        chain: Vec<NodeId>,
        out_ops: Vec<NodeId>,
        upd: Vec<NodeId>,
    },
    /// `chain[gi]` was overwritten by a splice.
    Chain { gi: usize, old: Vec<NodeId> },
    /// [`MutableGraph::rescale_workers`] shrank the cluster: the old
    /// worker count and the displaced scheme (whose server-fleet sizing
    /// depends on the machine count).
    SpecCluster { n_workers: usize, scheme: crate::config::CommScheme },
    /// [`MutableGraph::rescale_workers`] truncated the per-worker index
    /// rows; the undo re-extends them and restores `n_workers`.
    WorkerTail {
        comp_rows: Vec<Vec<NodeId>>,
        in_tails: Vec<Vec<NodeId>>,
        out_tails: Vec<Vec<NodeId>>,
        upd_tails: Vec<Vec<NodeId>>,
    },
}

/// Token for one open transaction (see [`MutableGraph::begin`]). Consumed
/// by [`MutableGraph::commit_txn`] / [`MutableGraph::rollback`] so a
/// transaction cannot be resolved twice; dropping it without resolving is a
/// bug the next `begin` panics on.
#[must_use = "resolve the transaction with commit_txn() or rollback()"]
pub struct Txn {
    _priv: (),
}

/// A global DFG plus the [`JobSpec`] it was built from, kept mutually
/// consistent under in-place plan edits. See module docs.
pub struct MutableGraph {
    spec: JobSpec,
    dfg: Dfg,
    n_workers: usize,
    /// false for tombstoned nodes
    alive: Vec<bool>,
    /// comp node of (worker, fusion group): `comp[w][g]`
    comp: Vec<Vec<NodeId>>,
    /// per comm group, in canonical creation order:
    in_ops: Vec<Vec<NodeId>>,
    chain: Vec<Vec<NodeId>>,
    out_ops: Vec<Vec<NodeId>>,
    upd_ops: Vec<Vec<NodeId>>,
    /// canonical ranks, refreshed by [`Self::commit`]
    canon: Vec<u64>,
    /// transaction-id counter continuing past the initial build
    txid: u64,
    // accumulated changelog
    removed: Vec<NodeId>,
    revived: Vec<NodeId>,
    touched: Vec<NodeId>,
    added_from: NodeId,
    // open-transaction state: inverse edits, recorded only while open
    journal: Vec<UndoOp>,
    txn_open: bool,
}

impl MutableGraph {
    /// Build the global DFG for `spec` (one full construction — the last
    /// one the search loop will ever do) and index it for mutation.
    pub fn new(spec: JobSpec) -> MutableGraph {
        let g = build_global_nameless(&spec, &AnalyticCost::new(&spec));
        MutableGraph::from_built(spec, g)
    }

    /// Index an already-built global DFG (must have been built from `spec`).
    pub fn from_built(spec: JobSpec, g: GlobalDfg) -> MutableGraph {
        let GlobalDfg { dfg, comp_node, group_nodes, update_node, n_workers, .. } = g;
        let n = dfg.len();
        let n_groups = spec.plan.groups.len();
        let n_fusion = spec.fusion.groups.len();

        let mut comp = vec![vec![0 as NodeId; n_fusion]; n_workers];
        for ((w, gi), id) in comp_node {
            comp[w as usize][gi as usize] = id;
        }

        let mut in_ops = vec![Vec::new(); n_groups];
        let mut chain = vec![Vec::new(); n_groups];
        let mut out_ops = vec![Vec::new(); n_groups];
        for (gi, nodes) in group_nodes.into_iter().enumerate() {
            // group_nodes is [In ops (worker order)] ++ [chain, creation
            // order] ++ [Out ops (worker order)] by construction
            for id in nodes {
                match dfg.node(id).kind {
                    OpKind::In => in_ops[gi].push(id),
                    OpKind::Out => out_ops[gi].push(id),
                    _ => chain[gi].push(id),
                }
            }
        }
        let mut upd_ops = vec![vec![0 as NodeId; n_workers]; n_groups];
        for ((w, gi), id) in update_node {
            upd_ops[gi][w as usize] = id;
        }

        let mut mg = MutableGraph {
            spec,
            dfg,
            n_workers,
            alive: vec![true; n],
            comp,
            in_ops,
            chain,
            out_ops,
            upd_ops,
            canon: vec![u64::MAX; n],
            // initial build starts txids at 1; continue safely past any of
            // them (txids only matter for trace joins, never for replay)
            txid: 1u64 << 32,
            removed: Vec::new(),
            revived: Vec::new(),
            touched: Vec::new(),
            added_from: 0, // first commit() reports the whole graph as new
            journal: Vec::new(),
            txn_open: false,
        };
        mg.refresh();
        mg
    }

    /// The current (edited) job spec.
    pub fn spec(&self) -> &JobSpec {
        &self.spec
    }

    /// The live graph arena (tombstones included; check [`Self::alive`]).
    pub fn dfg(&self) -> &Dfg {
        &self.dfg
    }

    /// Per-node liveness (false = tombstoned).
    pub fn alive(&self) -> &[bool] {
        &self.alive
    }

    /// Plan-derived canonical device ranks (replay tie-breaks).
    pub fn canon_ranks(&self) -> &[u64] {
        &self.canon
    }

    /// Worker count of the job.
    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    /// Current comm-group count of the plan.
    pub fn n_groups(&self) -> usize {
        self.spec.plan.groups.len()
    }

    /// Comp node executing fusion group `fg` on `worker`, if in range.
    pub fn comp_node(&self, worker: u16, fg: u32) -> Option<NodeId> {
        self.comp.get(worker as usize).and_then(|row| row.get(fg as usize)).copied()
    }

    /// All live nodes of comm group `gi` (In ops, chain, Out ops).
    pub fn group_nodes_iter(&self, gi: usize) -> impl Iterator<Item = NodeId> + '_ {
        self.in_ops[gi]
            .iter()
            .chain(self.chain[gi].iter())
            .chain(self.out_ops[gi].iter())
            .copied()
    }

    /// Update op of (worker, comm group).
    pub fn update_node(&self, worker: u16, gi: usize) -> NodeId {
        self.upd_ops[gi][worker as usize]
    }

    // ---- primitive edits ----------------------------------------------

    /// **Op fusion**: merge fusion groups `a` and `b` (same validity rules
    /// as [`passes::fuse_comp_groups`]); per worker the two comp nodes
    /// collapse into one fused-kernel node. Returns the kept group index.
    pub fn fuse_comp_groups(&mut self, a: usize, b: usize) -> Result<usize, PassError> {
        let n = self.spec.fusion.groups.len();
        if a >= n || b >= n {
            return Err(PassError::OutOfRange);
        }
        let saved = self.txn_open.then(|| {
            (
                self.spec.fusion.groups[a.min(b)].clone(),
                self.spec.fusion.groups[a.max(b)].clone(),
            )
        });
        let keep = passes::fuse_comp_groups(&mut self.spec, a, b)?;
        let drop = a.max(b); // passes keeps the smaller index
        debug_assert_eq!(keep, a.min(b));
        if let Some((old_kept, dropped)) = saved {
            self.journal.push(UndoOp::SpecOpFuse { keep, drop, old_kept, dropped });
        }
        let fused_dur =
            self.spec.fusion.duration(&self.spec.model, &self.spec.cluster.gpu, keep);
        for w in 0..self.n_workers {
            let ka = self.comp[w][keep];
            let kb = self.comp[w][drop];
            let preds: Vec<NodeId> = self.dfg.preds(kb).to_vec();
            let succs: Vec<NodeId> = self.dfg.succs(kb).to_vec();
            self.tombstone(kb);
            for p in preds {
                if p != ka {
                    self.edge_j(p, ka);
                }
            }
            for s in succs {
                if s != ka {
                    self.edge_j(ka, s);
                    self.touched.push(s);
                }
            }
            self.set_duration_j(ka, fused_dur);
            self.touched.push(ka);
        }
        if self.txn_open {
            let col: Vec<NodeId> = (0..self.n_workers).map(|w| self.comp[w][drop]).collect();
            self.journal.push(UndoOp::CompColumn { drop, col });
        }
        for w in 0..self.n_workers {
            self.comp[w].remove(drop);
        }
        Ok(keep)
    }

    /// **Tensor fusion**: merge comm groups `a` and `b` into one
    /// synchronization unit; the dropped group's subgraph is tombstoned
    /// and the kept chain re-spliced at the fused size. Returns the kept
    /// group index.
    pub fn fuse_tensor_groups(&mut self, a: usize, b: usize) -> Result<usize, PassError> {
        let n = self.spec.plan.groups.len();
        if a >= n || b >= n {
            return Err(PassError::OutOfRange);
        }
        let saved = self.txn_open.then(|| {
            (
                self.spec.plan.groups[a.min(b)].clone(),
                self.spec.plan.groups[a.max(b)].clone(),
            )
        });
        let keep = passes::fuse_tensor_groups(&mut self.spec, a, b)?;
        let drop = a.max(b);
        debug_assert_eq!(keep, a.min(b));
        if let Some((old_kept, dropped)) = saved {
            self.journal.push(UndoOp::SpecTensorFuse { keep, drop, old_kept, dropped });
        }
        // tombstone the dropped group's entire synchronization subgraph
        let doomed: Vec<NodeId> = self.in_ops[drop]
            .iter()
            .chain(self.chain[drop].iter())
            .chain(self.out_ops[drop].iter())
            .chain(self.upd_ops[drop].iter())
            .copied()
            .collect();
        for id in doomed {
            self.tombstone(id);
        }
        if self.txn_open {
            self.journal.push(UndoOp::GroupIndex {
                drop,
                in_ops: self.in_ops[drop].clone(),
                chain: self.chain[drop].clone(),
                out_ops: self.out_ops[drop].clone(),
                upd: self.upd_ops[drop].clone(),
            });
        }
        self.in_ops.remove(drop);
        self.chain.remove(drop);
        self.out_ops.remove(drop);
        self.upd_ops.remove(drop);
        // kept In ops now wait on every producer of the merged tensor set
        for w in 0..self.n_workers {
            let in_op = self.in_ops[keep][w];
            for ti in 0..self.spec.plan.groups[keep].tensors.len() {
                let t = self.spec.plan.groups[keep].tensors[ti];
                let Some(op) = self.spec.model.producer_of(t) else { continue };
                let pg = self.spec.fusion.group_of[op as usize] as usize;
                let comp = self.comp[w][pg];
                self.edge_j(comp, in_op);
            }
            self.touched.push(in_op);
        }
        self.rebuild_chain(keep);
        Ok(keep)
    }

    /// **Tensor partition**: slice comm group `g` into `k` pieces,
    /// re-splicing its chain if the count actually changes.
    pub fn set_partitions(&mut self, g: usize, k: usize) -> Result<(), PassError> {
        let old = self
            .spec
            .plan
            .groups
            .get(g)
            .map(|gr| gr.partitions)
            .ok_or(PassError::OutOfRange)?;
        passes::set_partitions(&mut self.spec, g, k)?;
        if self.spec.plan.groups[g].partitions != old {
            if self.txn_open {
                self.journal.push(UndoOp::SpecPartitions { g, old });
            }
            self.rebuild_chain(g);
        }
        Ok(())
    }

    /// **Template swap**: replace the model with a structurally-identical
    /// rewrite (same op and tensor counts — e.g. the mixed-precision pass,
    /// re-computation, or a half-batch gradient-accumulation template) and
    /// mirror it on the graph: every comp node's duration is refreshed and
    /// every comm chain whose fused byte size changed is re-spliced. The
    /// current fusion and comm plans are kept — a template swap composes
    /// with whatever fusions the search has already accepted.
    pub fn swap_model(&mut self, new_model: ModelGraph) -> Result<(), PassError> {
        if new_model.ops.len() != self.spec.model.ops.len()
            || new_model.tensors.len() != self.spec.model.tensors.len()
        {
            return Err(PassError::KindMismatch);
        }
        let old_bytes: Vec<f64> = (0..self.spec.plan.groups.len())
            .map(|gi| self.spec.plan.group_bytes(&self.spec.model, gi))
            .collect();
        let old_model = std::mem::replace(&mut self.spec.model, new_model);
        if self.txn_open {
            self.journal.push(UndoOp::SpecModel { old: old_model });
        }
        // refresh every comp node's duration from the new template
        for g in 0..self.spec.fusion.groups.len() {
            let dur =
                self.spec.fusion.duration(&self.spec.model, &self.spec.cluster.gpu, g);
            for w in 0..self.n_workers {
                let id = self.comp[w][g];
                self.set_duration_j(id, dur);
                self.touched.push(id);
            }
        }
        // re-splice only the chains whose synchronized bytes moved
        for gi in 0..self.spec.plan.groups.len() {
            let nb = self.spec.plan.group_bytes(&self.spec.model, gi);
            if nb != old_bytes[gi] {
                self.rebuild_chain(gi);
            }
        }
        Ok(())
    }

    /// **Elastic replan**: shrink the job from `n` to `new_n` workers in
    /// place — the recovery half of the fault model ([`crate::fault`]),
    /// and the edit behind the diagnosis engine's `continue-on:<k>`
    /// what-if ("is it worth continuing on the survivors?").
    ///
    /// The *last* `n − new_n` workers depart (survivor identities — and
    /// therefore their canonical ranks — are unchanged, which is what
    /// makes the result comparable bit-for-bit against a fresh `new_n`
    /// build): their comp, In/Out and update nodes are tombstoned, the
    /// per-worker index rows truncated, the cluster and scheme re-derived
    /// (PS fleets re-size from the new machine count), and every comm
    /// chain re-spliced through the same [`build_group_comm`] the full
    /// builder uses — zero `build_global*` calls. Inside an open
    /// transaction the whole rescale journals its inverse, so a
    /// [`Self::rollback`] restores the full fleet bit-exactly.
    ///
    /// Returns the number of departing-worker nodes tombstoned (the
    /// re-spliced chains are not counted). Errors with
    /// [`PassError::OutOfRange`] when `new_n` is zero or exceeds the
    /// current worker count; `new_n == n` is a no-op returning 0.
    pub fn rescale_workers(&mut self, new_n: usize) -> Result<usize, PassError> {
        let old_n = self.n_workers;
        if new_n == 0 || new_n > old_n {
            return Err(PassError::OutOfRange);
        }
        if new_n == old_n {
            return Ok(0);
        }
        if self.txn_open {
            self.journal.push(UndoOp::SpecCluster {
                n_workers: self.spec.cluster.n_workers,
                scheme: self.spec.scheme.clone(),
            });
        }
        self.spec.cluster.n_workers = new_n;
        self.spec.scheme = self.spec.scheme.resized_for(&self.spec.cluster);

        let comp_rows: Vec<Vec<NodeId>> = self.comp[new_n..].to_vec();
        let n_groups = self.in_ops.len();
        let mut in_tails: Vec<Vec<NodeId>> = Vec::with_capacity(n_groups);
        let mut out_tails: Vec<Vec<NodeId>> = Vec::with_capacity(n_groups);
        let mut upd_tails: Vec<Vec<NodeId>> = Vec::with_capacity(n_groups);
        for gi in 0..n_groups {
            in_tails.push(self.in_ops[gi][new_n..].to_vec());
            out_tails.push(self.out_ops[gi][new_n..].to_vec());
            upd_tails.push(self.upd_ops[gi][new_n..].to_vec());
        }
        if self.txn_open {
            self.journal.push(UndoOp::WorkerTail {
                comp_rows: comp_rows.clone(),
                in_tails: in_tails.clone(),
                out_tails: out_tails.clone(),
                upd_tails: upd_tails.clone(),
            });
        }
        // every node a departing worker owns; each tombstone journals its
        // own revival record, and chain nodes are handled by the rebuild
        let mut gone = 0usize;
        for row in comp_rows.iter().chain(&in_tails).chain(&out_tails).chain(&upd_tails) {
            for &id in row {
                self.tombstone(id);
                gone += 1;
            }
        }
        self.comp.truncate(new_n);
        for gi in 0..n_groups {
            self.in_ops[gi].truncate(new_n);
            self.out_ops[gi].truncate(new_n);
            self.upd_ops[gi].truncate(new_n);
        }
        self.n_workers = new_n;
        // every comm chain was sized for the old fleet — re-splice them
        // all from the shrunk spec (the rebuilt stages read the new
        // cluster shape, ring length, and server fleet)
        for gi in 0..n_groups {
            self.rebuild_chain(gi);
        }
        Ok(gone)
    }

    /// **Duration override**: overwrite one live node's expected duration
    /// as a journaled in-place edit — the primitive the diagnosis engine's
    /// what-if queries are made of (scale a link's ops, zero a comm chain,
    /// equalize a straggler GPU). Inside an open transaction the old value
    /// is journaled, so a [`Self::rollback`] restores it bit-exactly; the
    /// change lands in the next [`Self::commit`]'s `touched` set so the
    /// incremental replayer repairs exactly the affected cone. Returns
    /// `true` iff the duration actually changed (dead nodes and no-op
    /// writes return `false` and journal nothing).
    pub fn override_duration(&mut self, id: NodeId, dur: f64) -> bool {
        if !self.alive[id as usize] || self.dfg.node(id).duration == dur {
            return false;
        }
        self.set_duration_j(id, dur);
        self.touched.push(id);
        true
    }

    // ---- transactions ---------------------------------------------------

    /// Open a transaction: every subsequent primitive edit records its
    /// inverse until the returned token is resolved with
    /// [`Self::commit_txn`] (keep the edits) or [`Self::rollback`] (undo
    /// them all, with no rebuild and no spec clone).
    pub fn begin(&mut self) -> Txn {
        assert!(!self.txn_open, "nested MutableGraph transaction");
        self.txn_open = true;
        self.journal.clear();
        Txn { _priv: () }
    }

    /// True while a transaction is open.
    pub fn in_txn(&self) -> bool {
        self.txn_open
    }

    /// Accept the open transaction's edits: the journal is discarded and
    /// the edits become permanent.
    pub fn commit_txn(&mut self, txn: Txn) {
        let Txn { _priv: () } = txn;
        debug_assert!(self.txn_open);
        self.txn_open = false;
        self.journal.clear();
    }

    /// Reject the open transaction: replay the inverse-edit journal in
    /// reverse, restoring nodes, durations, plan indices and comm splices
    /// exactly as they were at [`Self::begin`]. Nodes appended by the
    /// transaction are tombstoned (ids are never reused); nodes it
    /// tombstoned are revived and reported via [`ChangeLog::revived`] so
    /// the incremental engine re-interns them.
    pub fn rollback(&mut self, txn: Txn) {
        let Txn { _priv: () } = txn;
        debug_assert!(self.txn_open);
        self.txn_open = false; // undo edits below must not re-journal
        while let Some(op) = self.journal.pop() {
            match op {
                UndoOp::SpecPartitions { g, old } => {
                    self.spec.plan.groups[g].partitions = old;
                }
                UndoOp::SpecTensorFuse { keep, drop, old_kept, dropped } => {
                    self.spec.plan.groups[keep] = old_kept;
                    self.spec.plan.groups.insert(drop, dropped);
                }
                UndoOp::SpecOpFuse { keep, drop, old_kept, dropped } => {
                    self.spec.fusion.groups[keep] = old_kept;
                    self.spec.fusion.groups.insert(drop, dropped);
                    self.spec.fusion.rebuild_index(self.spec.model.ops.len());
                }
                UndoOp::SpecModel { old } => {
                    self.spec.model = old;
                }
                UndoOp::EdgeAdded { from, to } => {
                    self.dfg.remove_edge(from, to);
                    self.touched.push(to);
                }
                UndoOp::Tombstoned { id, device, duration, template_id, preds, succs } => {
                    self.alive[id as usize] = true;
                    let node = self.dfg.node_mut(id);
                    node.device = device;
                    node.duration = duration;
                    node.template_id = template_id;
                    for p in preds {
                        self.dfg.edge(p, id);
                    }
                    for s in succs {
                        self.dfg.edge(id, s);
                        self.touched.push(s);
                    }
                    self.revived.push(id);
                }
                UndoOp::Appended { id } => {
                    // kill for good: detach and mark dead, like a tombstone
                    // but outside the (now closed) journal
                    self.alive[id as usize] = false;
                    self.dfg.detach(id);
                    let node = self.dfg.node_mut(id);
                    node.device = DeviceKey::Null;
                    node.duration = 0.0;
                    node.template_id = None;
                    self.removed.push(id);
                }
                UndoOp::Duration { id, old } => {
                    self.dfg.node_mut(id).duration = old;
                    self.touched.push(id);
                }
                UndoOp::TensorBytes { id, old } => {
                    if let Some(t) = &mut self.dfg.node_mut(id).tensor {
                        t.bytes = old;
                    }
                }
                UndoOp::CompColumn { drop, col } => {
                    for w in 0..self.n_workers {
                        self.comp[w].insert(drop, col[w]);
                    }
                }
                UndoOp::GroupIndex { drop, in_ops, chain, out_ops, upd } => {
                    self.in_ops.insert(drop, in_ops);
                    self.chain.insert(drop, chain);
                    self.out_ops.insert(drop, out_ops);
                    self.upd_ops.insert(drop, upd);
                }
                UndoOp::Chain { gi, old } => {
                    self.chain[gi] = old;
                }
                UndoOp::SpecCluster { n_workers, scheme } => {
                    self.spec.cluster.n_workers = n_workers;
                    self.spec.scheme = scheme;
                }
                UndoOp::WorkerTail { comp_rows, in_tails, out_tails, upd_tails } => {
                    // runs after the departing workers' Tombstoned undos
                    // (journal is popped in reverse), so the re-extended
                    // rows point at already-revived nodes
                    self.n_workers += comp_rows.len();
                    self.comp.extend(comp_rows);
                    for (gi, t) in in_tails.into_iter().enumerate() {
                        self.in_ops[gi].extend(t);
                    }
                    for (gi, t) in out_tails.into_iter().enumerate() {
                        self.out_ops[gi].extend(t);
                    }
                    for (gi, t) in upd_tails.into_iter().enumerate() {
                        self.upd_ops[gi].extend(t);
                    }
                }
            }
        }
    }

    // ---- bookkeeping ---------------------------------------------------

    /// Detach a node from the graph and mark it dead. Ids stay stable; the
    /// arena is never compacted (a 40-round search grows it by well under
    /// 2x, and the replayer's cost scales with *live* nodes). Inside a
    /// transaction, the node's fields and adjacency are journaled so a
    /// rollback can revive it verbatim.
    fn tombstone(&mut self, id: NodeId) {
        if !self.alive[id as usize] {
            return;
        }
        if self.txn_open {
            let node = self.dfg.node(id);
            self.journal.push(UndoOp::Tombstoned {
                id,
                device: node.device,
                duration: node.duration,
                template_id: node.template_id,
                preds: self.dfg.preds(id).to_vec(),
                succs: self.dfg.succs(id).to_vec(),
            });
        }
        self.alive[id as usize] = false;
        self.dfg.detach(id);
        let node = self.dfg.node_mut(id);
        node.device = DeviceKey::Null;
        node.duration = 0.0;
        node.template_id = None;
        self.removed.push(id);
    }

    /// Insert an edge, journaling the inverse iff it was newly inserted.
    fn edge_j(&mut self, from: NodeId, to: NodeId) {
        if self.dfg.edge(from, to) && self.txn_open {
            self.journal.push(UndoOp::EdgeAdded { from, to });
        }
    }

    /// Overwrite a node's duration, journaling the old value on change.
    fn set_duration_j(&mut self, id: NodeId, dur: f64) {
        let old = self.dfg.node(id).duration;
        if old != dur {
            if self.txn_open {
                self.journal.push(UndoOp::Duration { id, old });
            }
            self.dfg.node_mut(id).duration = dur;
        }
    }

    /// Overwrite a node's tensor-meta bytes, journaling the old value.
    fn set_tensor_bytes_j(&mut self, id: NodeId, bytes: f64) {
        let Some(old) = self.dfg.node(id).tensor.map(|t| t.bytes) else { return };
        if old != bytes {
            if self.txn_open {
                self.journal.push(UndoOp::TensorBytes { id, old });
            }
            if let Some(t) = &mut self.dfg.node_mut(id).tensor {
                t.bytes = bytes;
            }
        }
    }

    /// Tombstone group `gi`'s comm chain and rebuild it from the current
    /// spec via the same builder the full construction uses.
    fn rebuild_chain(&mut self, gi: usize) {
        if self.txn_open {
            self.journal.push(UndoOp::Chain { gi, old: self.chain[gi].clone() });
        }
        for &id in self.chain[gi].clone().iter() {
            self.tombstone(id);
        }
        self.chain[gi].clear();

        let watermark = self.dfg.len() as NodeId;
        let mut out_per_worker: Vec<Vec<NodeId>> = vec![Vec::new(); self.n_workers];
        let mut gnodes: Vec<NodeId> = Vec::new();
        {
            let cost = AnalyticCost::new(&self.spec);
            build_group_comm(
                &mut self.dfg,
                &self.spec,
                &cost,
                false,
                gi,
                &self.in_ops[gi],
                &mut out_per_worker,
                &mut gnodes,
                &mut self.txid,
            );
        }
        self.chain[gi] = gnodes;
        let n = self.dfg.len();
        self.alive.resize(n, true);
        self.canon.resize(n, u64::MAX);
        if self.txn_open {
            // edges created by the lowering are always incident to at least
            // one appended node, so killing the appended nodes on rollback
            // removes them all — only the appends themselves are journaled
            for id in watermark..n as NodeId {
                self.journal.push(UndoOp::Appended { id });
            }
        }

        let gbytes = self.spec.plan.group_bytes(&self.spec.model, gi);
        let upd_dur = AnalyticCost::new(&self.spec).update(gbytes);
        for w in 0..self.n_workers {
            let out = self.out_ops[gi][w];
            for ti in 0..out_per_worker[w].len() {
                let o = out_per_worker[w][ti];
                self.edge_j(o, out);
            }
            self.touched.push(out);
            self.set_tensor_bytes_j(out, gbytes);
            let in_op = self.in_ops[gi][w];
            self.set_tensor_bytes_j(in_op, gbytes);
            let upd = self.upd_ops[gi][w];
            self.set_duration_j(upd, upd_dur);
            self.set_tensor_bytes_j(upd, gbytes);
            self.touched.push(upd);
        }
    }

    /// Re-derive the per-node fields that depend on *current* plan indices
    /// (canonical ranks, comp `template_id`, comm `tensor_id`) and return
    /// the accumulated [`ChangeLog`]. Call once per round, after applying
    /// a batch of decisions and before replaying; every returned log must
    /// be forwarded to the engine's next `replay_incremental` (dropping
    /// one would hide its edits from the repair passes).
    pub fn commit(&mut self) -> ChangeLog {
        // note: calling commit() with a transaction open is the designed
        // flow — the candidate is replayed on the committed changelog, then
        // kept (commit_txn) or undone (rollback, whose inverse effects land
        // in the *next* changelog)
        self.refresh();
        let mut removed = std::mem::take(&mut self.removed);
        let mut revived = std::mem::take(&mut self.revived);
        // a node tombstoned and revived (or vice versa) within one commit
        // window must reach the engine only under its *final* state
        removed.retain(|&id| !self.alive[id as usize]);
        revived.retain(|&id| self.alive[id as usize]);
        let log = ChangeLog {
            removed,
            revived,
            touched: std::mem::take(&mut self.touched),
            added_from: self.added_from,
        };
        self.added_from = self.dfg.len() as NodeId;
        log
    }

    fn refresh(&mut self) {
        let n = self.dfg.len();
        self.alive.resize(n, true);
        self.canon.resize(n, u64::MAX);
        for w in 0..self.n_workers {
            for g in 0..self.comp[w].len() {
                let id = self.comp[w][g];
                self.canon[id as usize] = canon_rank(0, w as u64, g as u64);
                self.dfg.node_mut(id).template_id = Some(g as u32);
            }
        }
        for gi in 0..self.in_ops.len() {
            let mut seq = 0u64;
            for part in 0..3 {
                let len = match part {
                    0 => self.in_ops[gi].len(),
                    1 => self.chain[gi].len(),
                    _ => self.out_ops[gi].len(),
                };
                for k in 0..len {
                    let id = match part {
                        0 => self.in_ops[gi][k],
                        1 => self.chain[gi][k],
                        _ => self.out_ops[gi][k],
                    };
                    self.canon[id as usize] = canon_rank(1, gi as u64, seq);
                    seq += 1;
                    if let Some(t) = &mut self.dfg.node_mut(id).tensor {
                        t.tensor_id = gi as u32;
                    }
                }
            }
            for w in 0..self.n_workers {
                let id = self.upd_ops[gi][w];
                self.canon[id as usize] = canon_rank(2, gi as u64, w as u64);
                if let Some(t) = &mut self.dfg.node_mut(id).tensor {
                    t.tensor_id = gi as u32;
                }
            }
        }
    }

    /// Count of live (non-tombstoned) nodes.
    pub fn n_alive(&self) -> usize {
        self.alive.iter().filter(|&&a| a).count()
    }

    /// Debug validation: the spec's plans stay valid partitions and the
    /// graph stays acyclic.
    pub fn validate(&self) -> Result<(), String> {
        self.spec.plan.validate(&self.spec.model)?;
        self.spec.fusion.validate(&self.spec.model)?;
        if !self.dfg.is_dag() {
            return Err("mutable graph has a cycle".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Transport;

    fn mg(model: &str, scheme: &str) -> MutableGraph {
        MutableGraph::new(JobSpec::standard(model, scheme, Transport::Rdma))
    }

    #[test]
    fn op_fusion_merges_comp_nodes_in_place() {
        let mut m = mg("vgg16", "horovod");
        let n0 = m.dfg().len();
        let keep = m.fuse_comp_groups(0, 1).unwrap();
        assert_eq!(keep, 0);
        assert_eq!(m.dfg().len(), n0, "op fusion must not allocate nodes");
        assert_eq!(m.n_alive(), n0 - m.n_workers());
        assert_eq!(m.validate(), Ok(()));
        let log = m.commit();
        assert_eq!(log.removed.len(), m.n_workers());
        assert!(!log.touched.is_empty());
    }

    #[test]
    fn tensor_fusion_splices_chain() {
        let mut m = mg("resnet50", "horovod");
        let groups0 = m.n_groups();
        m.fuse_tensor_groups(0, 1).unwrap();
        assert_eq!(m.n_groups(), groups0 - 1);
        assert_eq!(m.validate(), Ok(()));
        // the kept group's In ops wait on both producers
        let in0 = m.in_ops[0][0];
        assert!(!m.dfg().preds(in0).is_empty());
        // tombstones are detached
        let log = m.commit();
        for &r in &log.removed {
            assert!(m.dfg().preds(r).is_empty() && m.dfg().succs(r).is_empty());
        }
    }

    #[test]
    fn partition_rebuilds_only_that_chain() {
        let mut m = mg("vgg16", "byteps");
        let chain_len0 = m.chain[3].len();
        m.set_partitions(3, 4).unwrap();
        assert_eq!(m.spec().plan.groups[3].partitions, 4);
        assert!(m.chain[3].len() > chain_len0, "4-way chain has more nodes");
        // setting the same count again is a no-op
        let _ = m.commit();
        m.set_partitions(3, 4).unwrap();
        let log = m.commit();
        assert!(log.is_empty(m.dfg().len()));
        assert_eq!(m.validate(), Ok(()));
    }

    #[test]
    fn rescale_workers_shrinks_the_fleet_in_place() {
        let mut m = mg("vgg16", "horovod");
        let n0 = m.n_workers();
        let gone = m.rescale_workers(n0 - 2).unwrap();
        assert!(gone > 0, "departing workers own nodes");
        assert_eq!(m.n_workers(), n0 - 2);
        assert_eq!(m.spec().cluster.n_workers, n0 - 2);
        assert_eq!(m.validate(), Ok(()));
        let log = m.commit();
        assert!(!log.removed.is_empty());
        // no-op and out-of-range paths
        assert_eq!(m.rescale_workers(n0 - 2).unwrap(), 0);
        assert!(m.rescale_workers(0).is_err());
        assert!(m.rescale_workers(n0 + 1).is_err());
        // ranks stay unique among the survivors
        let mut seen = std::collections::HashSet::new();
        for i in m.dfg().ids() {
            if m.alive()[i as usize] {
                assert!(seen.insert(m.canon_ranks()[i as usize]), "duplicate canon rank");
            }
        }
    }

    #[test]
    fn rescale_resizes_the_server_fleet() {
        // 16 workers / 8 per machine = 2 colocated servers; dropping to
        // one machine must shrink the fleet the way a fresh parse would
        let mut m = mg("resnet50", "byteps");
        assert_eq!(m.spec().scheme.n_servers(), 2);
        m.rescale_workers(8).unwrap();
        assert_eq!(m.spec().scheme.n_servers(), 1);
        assert_eq!(m.validate(), Ok(()));
    }

    #[test]
    fn canon_ranks_unique_among_live_nodes() {
        let mut m = mg("resnet50", "byteps");
        m.fuse_tensor_groups(2, 5).unwrap();
        m.fuse_comp_groups(0, 1).unwrap();
        m.set_partitions(0, 3).unwrap();
        let _ = m.commit();
        let mut seen = std::collections::HashSet::new();
        for i in m.dfg().ids() {
            if m.alive()[i as usize] {
                assert!(seen.insert(m.canon_ranks()[i as usize]), "duplicate canon rank");
            }
        }
        assert_eq!(m.validate(), Ok(()));
    }
}
