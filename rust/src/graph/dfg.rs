//! The data-flow-graph arena shared by local DFGs, the global DFG, and all
//! rewritten graphs produced by optimization passes.
//!
//! Vertices are computation ops and *fine-grained* communication ops
//! (paper §4.1); edges are dependencies. The same structure carries the
//! execution graph the replayer derives (extra ordering edges are kept in a
//! side list so the original DFG is never mutated).

use crate::util::intern::{self, OpId};
use crate::util::Us;

/// Node index inside one `Dfg`.
pub type NodeId = u32;

/// Identifier of a logical tensor (gradient) in the model template.
/// Fused tensors get fresh ids above the template range.
pub type TensorId = u32;

/// Kind of op in the global DFG.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Forward computation op.
    Forward,
    /// Backward computation op (may produce gradient tensors).
    Backward,
    /// Parameter update op (after a tensor's synchronization completes).
    Update,
    /// Communication-library negotiation/coordination op (e.g. Horovod's
    /// coordinator cycle) — fine-grained comm op, runs on the coordinator.
    Negotiate,
    /// Producer side of one tensor-(partition)-chunk transmission.
    Send,
    /// Consumer side of one tensor-(partition)-chunk transmission.
    Recv,
    /// Server-side aggregation of a pushed partition (PS architecture).
    Aggregate,
    /// Virtual op marking where a tensor leaves a local DFG (no cost).
    In,
    /// Virtual op marking where a synchronized tensor re-enters (no cost).
    Out,
}

impl OpKind {
    /// Computation family (FW/BW/UPD) — serializes on a worker GPU.
    pub fn is_comp(self) -> bool {
        matches!(self, OpKind::Forward | OpKind::Backward | OpKind::Update)
    }

    /// Fine-grained communication family (SEND/RECV/NEG/AGG).
    pub fn is_comm(self) -> bool {
        matches!(
            self,
            OpKind::Send | OpKind::Recv | OpKind::Negotiate | OpKind::Aggregate
        )
    }

    /// Zero-cost marker ops (In/Out) that never appear in traces.
    pub fn is_virtual(self) -> bool {
        matches!(self, OpKind::In | OpKind::Out)
    }
}

/// The execution resource an op occupies; the replayer serializes ops that
/// share a device (paper §4.3 treats "each worker/PS and each communication
/// link as one device").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DeviceKey {
    /// GPU compute stream of worker `w`.
    Gpu(u16),
    /// Transmit side of the NIC/link of node `n` (worker or server).
    LinkTx(u16),
    /// Receive side of the NIC/link of node `n`.
    LinkRx(u16),
    /// CPU aggregation resource of PS server `s`.
    PsCpu(u16),
    /// Intra-machine interconnect (NVLink/PCIe) of machine `m`; carries
    /// local reduce/broadcast and worker↔colocated-server transfers.
    NvLink(u16),
    /// The AllReduce coordinator (negotiation cycles).
    Coordinator,
    /// Ops that take time but occupy no exclusive resource (virtual In/Out
    /// ops, negotiation delays): never queue, may still have a duration.
    Null,
}

/// Process id of the AllReduce coordinator in trace events.
pub const COORD_PROC: u16 = u16::MAX;

/// Tensor (partition) metadata attached to comm ops and to the Backward op
/// that produces the tensor.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TensorMeta {
    /// The logical tensor this op works on.
    pub tensor_id: TensorId,
    /// Size in bytes of the tensor *piece* this op moves (full tensor for
    /// In/Out, chunk for ring steps, partition for PS pieces).
    pub bytes: f64,
}

/// A vertex of the DFG.
#[derive(Clone, Debug)]
pub struct Node {
    /// Interned op name (the trace join key; [`OpId::EMPTY`] on the
    /// nameless fast path). Resolve via [`OpId::resolve`] only at
    /// report/JSON/trace boundaries — the replay hot path compares ids.
    pub name: OpId,
    /// Op kind.
    pub kind: OpKind,
    /// Execution resource the op serializes on.
    pub device: DeviceKey,
    /// Expected execution time (profiled average) in microseconds.
    pub duration: Us,
    /// Worker (or server) that owns the op; used for per-worker breakdowns.
    pub owner: u16,
    /// Process that executes and *timestamps* the op: worker id, or
    /// `n_workers + s` for PS server `s`, or [`COORD_PROC`] for the
    /// AllReduce coordinator. Trace alignment solves one clock offset per
    /// process (paper §4.2).
    pub proc: u16,
    /// Tensor (piece) the op moves, for comm ops and gradient producers.
    pub tensor: Option<TensorMeta>,
    /// Unique transaction id matching a Send to its Recv (paper §4.1).
    pub txid: Option<u64>,
    /// For comp ops: index of the op in the model template (same on every
    /// data-parallel worker — used by the symmetry acceleration).
    pub template_id: Option<u32>,
}

impl Node {
    /// Zero-cost, device-less marker node (In/Out ops).
    pub fn virtual_op(name: OpId, kind: OpKind, owner: u16) -> Node {
        Node {
            name,
            kind,
            device: DeviceKey::Null,
            duration: 0.0,
            owner,
            proc: owner,
            tensor: None,
            txid: None,
            template_id: None,
        }
    }
}

/// Directed acyclic graph over `Node`s with forward and reverse adjacency.
#[derive(Clone, Debug, Default)]
pub struct Dfg {
    /// The node arena; ids are indices and stay stable forever.
    pub nodes: Vec<Node>,
    succs: Vec<Vec<NodeId>>,
    preds: Vec<Vec<NodeId>>,
}

impl Dfg {
    /// Empty graph.
    pub fn new() -> Dfg {
        Dfg::default()
    }

    /// Append a node, returning its stable id.
    pub fn add(&mut self, node: Node) -> NodeId {
        let id = self.nodes.len() as NodeId;
        self.nodes.push(node);
        self.succs.push(Vec::new());
        self.preds.push(Vec::new());
        id
    }

    /// Insert a dependency edge. Returns `true` iff the edge was newly
    /// inserted (duplicates are ignored) — the transaction journal of
    /// [`crate::graph::mutable::MutableGraph`] records only real inserts so
    /// a rollback never removes a pre-existing edge.
    pub fn edge(&mut self, from: NodeId, to: NodeId) -> bool {
        debug_assert_ne!(
            from,
            to,
            "self edge on {}",
            self.nodes[from as usize].name.resolve()
        );
        if !self.succs[from as usize].contains(&to) {
            self.succs[from as usize].push(to);
            self.preds[to as usize].push(from);
            true
        } else {
            false
        }
    }

    /// Remove a directed edge if present (no-op otherwise). Adjacency lists
    /// are sets, not sequences: `swap_remove` is safe because nothing in the
    /// crate depends on neighbor order for its *values* (replay start times
    /// are max-reductions over predecessors).
    pub fn remove_edge(&mut self, from: NodeId, to: NodeId) {
        if let Some(p) = self.succs[from as usize].iter().position(|&s| s == to) {
            self.succs[from as usize].swap_remove(p);
        }
        if let Some(p) = self.preds[to as usize].iter().position(|&s| s == from) {
            self.preds[to as usize].swap_remove(p);
        }
    }

    /// Disconnect a node from every neighbor. Tombstoning support for the
    /// mutable-plan layer ([`crate::graph::mutable`]): the node stays in the
    /// arena (ids are stable) but no longer participates in any dependency.
    pub fn detach(&mut self, id: NodeId) {
        let succs = std::mem::take(&mut self.succs[id as usize]);
        for s in succs {
            if let Some(p) = self.preds[s as usize].iter().position(|&x| x == id) {
                self.preds[s as usize].swap_remove(p);
            }
        }
        let preds = std::mem::take(&mut self.preds[id as usize]);
        for p in preds {
            if let Some(q) = self.succs[p as usize].iter().position(|&x| x == id) {
                self.succs[p as usize].swap_remove(q);
            }
        }
    }

    /// Node count (tombstoned nodes included).
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True when the arena holds no nodes.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Node by id.
    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id as usize]
    }

    /// Mutable node by id.
    pub fn node_mut(&mut self, id: NodeId) -> &mut Node {
        &mut self.nodes[id as usize]
    }

    /// Successor ids of a node.
    pub fn succs(&self, id: NodeId) -> &[NodeId] {
        &self.succs[id as usize]
    }

    /// Predecessor ids of a node.
    pub fn preds(&self, id: NodeId) -> &[NodeId] {
        &self.preds[id as usize]
    }

    /// All node ids, ascending.
    pub fn ids(&self) -> impl Iterator<Item = NodeId> + '_ {
        (0..self.nodes.len() as NodeId).into_iter()
    }

    /// Kahn topological order; panics if the graph has a cycle (graphs are
    /// constructed acyclic; a cycle is a builder bug worth failing loudly).
    pub fn topo_order(&self) -> Vec<NodeId> {
        let mut indeg: Vec<u32> = self.preds.iter().map(|p| p.len() as u32).collect();
        let mut ready: Vec<NodeId> =
            self.ids().filter(|&i| indeg[i as usize] == 0).collect();
        let mut order = Vec::with_capacity(self.len());
        while let Some(id) = ready.pop() {
            order.push(id);
            for &s in self.succs(id) {
                indeg[s as usize] -= 1;
                if indeg[s as usize] == 0 {
                    ready.push(s);
                }
            }
        }
        assert_eq!(order.len(), self.len(), "cycle in DFG");
        order
    }

    /// True if the graph is acyclic (used by tests and pass validation).
    pub fn is_dag(&self) -> bool {
        let mut indeg: Vec<u32> = self.preds.iter().map(|p| p.len() as u32).collect();
        let mut ready: Vec<NodeId> =
            self.ids().filter(|&i| indeg[i as usize] == 0).collect();
        let mut seen = 0usize;
        while let Some(id) = ready.pop() {
            seen += 1;
            for &s in self.succs(id) {
                indeg[s as usize] -= 1;
                if indeg[s as usize] == 0 {
                    ready.push(s);
                }
            }
        }
        seen == self.len()
    }

    /// Sum of durations of all comp ops owned by `worker` of a given kind —
    /// used for FW/BW breakdown reports (paper Table 2).
    pub fn comp_time(&self, worker: u16, kind: OpKind) -> Us {
        self.nodes
            .iter()
            .filter(|n| n.owner == worker && n.kind == kind)
            .map(|n| n.duration)
            .sum()
    }

    /// Find node id by exact name (slow; test/report helper). A name
    /// that was never interned cannot belong to any node.
    pub fn find(&self, name: &str) -> Option<NodeId> {
        let id = intern::lookup(name)?;
        self.nodes.iter().position(|n| n.name == id).map(|i| i as NodeId)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn comp(name: &str, dur: Us) -> Node {
        Node {
            name: intern::intern(name),
            kind: OpKind::Forward,
            device: DeviceKey::Gpu(0),
            duration: dur,
            owner: 0,
            proc: 0,
            tensor: None,
            txid: None,
            template_id: None,
        }
    }

    #[test]
    fn add_edges_and_topo() {
        let mut g = Dfg::new();
        let a = g.add(comp("a", 1.0));
        let b = g.add(comp("b", 1.0));
        let c = g.add(comp("c", 1.0));
        g.edge(a, b);
        g.edge(b, c);
        g.edge(a, c);
        let order = g.topo_order();
        let pos = |id: NodeId| order.iter().position(|&x| x == id).unwrap();
        assert!(pos(a) < pos(b) && pos(b) < pos(c));
        assert!(g.is_dag());
    }

    #[test]
    fn duplicate_edge_ignored() {
        let mut g = Dfg::new();
        let a = g.add(comp("a", 1.0));
        let b = g.add(comp("b", 1.0));
        g.edge(a, b);
        g.edge(a, b);
        assert_eq!(g.succs(a).len(), 1);
        assert_eq!(g.preds(b).len(), 1);
    }

    #[test]
    fn remove_edge_and_detach() {
        let mut g = Dfg::new();
        let a = g.add(comp("a", 1.0));
        let b = g.add(comp("b", 1.0));
        let c = g.add(comp("c", 1.0));
        g.edge(a, b);
        g.edge(b, c);
        g.edge(a, c);
        g.remove_edge(a, c);
        assert_eq!(g.succs(a), &[b]);
        assert_eq!(g.preds(c), &[b]);
        g.remove_edge(a, c); // no-op on absent edge
        g.detach(b);
        assert!(g.succs(b).is_empty() && g.preds(b).is_empty());
        assert!(g.succs(a).is_empty());
        assert!(g.preds(c).is_empty());
        assert!(g.is_dag());
    }

    #[test]
    fn cycle_detected() {
        let mut g = Dfg::new();
        let a = g.add(comp("a", 1.0));
        let b = g.add(comp("b", 1.0));
        g.edge(a, b);
        g.edge(b, a);
        assert!(!g.is_dag());
    }

    #[test]
    fn comp_time_breakdown() {
        let mut g = Dfg::new();
        g.add(comp("f1", 5.0));
        let mut bw = comp("b1", 7.0);
        bw.kind = OpKind::Backward;
        g.add(bw);
        assert_eq!(g.comp_time(0, OpKind::Forward), 5.0);
        assert_eq!(g.comp_time(0, OpKind::Backward), 7.0);
        assert_eq!(g.comp_time(1, OpKind::Forward), 0.0);
    }
}
