//! The campaign results layer: one CSV + one schema-stable JSON matrix.
//!
//! Rows are the expanded cells in sorted-id order; values come from the
//! journal's `done` events (the journal is the single source of truth —
//! the matrix is always a pure function of journal + spec, which is
//! what makes kill-and-resume reproduce an uninterrupted run
//! bit-for-bit). Every row carries provenance: the spec hash, the git
//! describe of the producing build, the replay mode requested and the
//! mode actually used (tiered may demote), the per-cell result hash,
//! and wall time.

use super::queue::{CellState, JournalState};
use super::spec::Cell;
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Matrix schema version (bumped on column/key changes).
pub const MATRIX_VERSION: f64 = 1.1;

/// Result-object keys emitted as CSV columns, in order. Every `done`
/// result carries all of these (inapplicable ones as JSON `null` → an
/// empty CSV field), so the header never varies with spec contents.
pub const RESULT_COLUMNS: [&str; 15] = [
    "iteration_us",
    "fw_us",
    "bw_us",
    "est_peak_mem_bytes",
    "ops",
    "mode_used",
    "demoted",
    "trace_warnings",
    "path_comp_us",
    "path_comm_us",
    "top_bottleneck",
    "perfect_overlap_speedup",
    "opt_us",
    "opt_speedup",
    "executor",
];

/// Executor self-telemetry keys, appended after [`RESULT_COLUMNS`] in
/// the CSV. The executor merges them into the result object **after**
/// the result hash is computed (and zeroes them under a fixed wall
/// time), so they never enter `result_hash` and never perturb the
/// bit-for-bit kill-and-resume property.
pub const TELEMETRY_COLUMNS: [&str; 4] = [
    "tele_replay_us",
    "tele_diagnose_us",
    "tele_optimize_us",
    "tele_queue_depth",
];

/// One matrix row: a cell plus its journal outcome.
#[derive(Debug, Clone)]
pub struct Row {
    /// The expanded cell.
    pub cell: Cell,
    /// `done` | `failed` | `pending` (never started or interrupted).
    pub status: String,
    /// Execution wall time (ms); 0 unless done.
    pub wall_ms: f64,
    /// Hash of the timing-independent result fields; empty unless done.
    pub result_hash: String,
    /// Failure reason; empty unless failed.
    pub reason: String,
    /// The per-cell result object; empty object unless done.
    pub result: Json,
}

/// The assembled results matrix.
#[derive(Debug)]
pub struct Matrix {
    /// Campaign name.
    pub campaign: String,
    /// Hash of the canonical spec.
    pub spec_hash: String,
    /// `git describe` of the producing build (or an override).
    pub git: String,
    /// Rows in sorted cell-id order.
    pub rows: Vec<Row>,
}

/// Escape one CSV field per RFC 4180: quote when it contains a comma,
/// quote, or newline; double internal quotes.
fn csv_escape(field: &str) -> String {
    if field.contains(',') || field.contains('"') || field.contains('\n') {
        format!("\"{}\"", field.replace('"', "\"\""))
    } else {
        field.to_string()
    }
}

/// Render a JSON scalar as a CSV field (null → empty; numbers via the
/// deterministic [`Json`] writer, so integers print without a decimal).
fn csv_value(v: Option<&Json>) -> String {
    match v {
        None | Some(Json::Null) => String::new(),
        Some(Json::Str(s)) => csv_escape(s),
        Some(other) => csv_escape(&other.to_string()),
    }
}

impl Matrix {
    /// Assemble the matrix for `cells` from a reduced journal. Cells
    /// absent from the journal — or left `running` by a kill — appear
    /// as `pending` rows, so a budget-truncated campaign still emits a
    /// complete, honest matrix.
    pub fn from_state(state: &JournalState, cells: &[Cell], git: &str) -> Matrix {
        let mut rows: Vec<Row> = cells
            .iter()
            .map(|cell| {
                let id = cell.id();
                let (status, wall_ms, result_hash, reason, result) = match state.cells.get(&id) {
                    Some(CellState::Done { result_hash, wall_ms, result }) => (
                        "done",
                        *wall_ms,
                        result_hash.clone(),
                        String::new(),
                        result.clone(),
                    ),
                    Some(CellState::Failed { reason }) => {
                        ("failed", 0.0, String::new(), reason.clone(), Json::obj())
                    }
                    Some(CellState::Running) | None => {
                        ("pending", 0.0, String::new(), String::new(), Json::obj())
                    }
                };
                Row {
                    cell: cell.clone(),
                    status: status.to_string(),
                    wall_ms,
                    result_hash,
                    reason,
                    result,
                }
            })
            .collect();
        rows.sort_by(|a, b| a.cell.id().cmp(&b.cell.id()));
        Matrix {
            campaign: state.campaign.clone(),
            spec_hash: state.spec_hash.clone(),
            git: git.to_string(),
            rows,
        }
    }

    /// Count of rows with `status`.
    pub fn count(&self, status: &str) -> usize {
        self.rows.iter().filter(|r| r.status == status).count()
    }

    /// The CSV document (fixed header, sorted rows).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str("cell,model,scheme,workers,strategies,inject,replay_mode,status");
        for col in RESULT_COLUMNS.iter().chain(TELEMETRY_COLUMNS.iter()) {
            out.push(',');
            out.push_str(col);
        }
        out.push_str(",wall_ms,result_hash,spec_hash,git,reason\n");
        for row in &self.rows {
            let c = &row.cell;
            let mut fields = vec![
                csv_escape(&c.id()),
                csv_escape(&c.model),
                csv_escape(&c.scheme),
                c.workers.to_string(),
                csv_escape(&c.strategies),
                csv_escape(&c.inject),
                c.mode.name().to_string(),
                row.status.clone(),
            ];
            for col in RESULT_COLUMNS.iter().chain(TELEMETRY_COLUMNS.iter()) {
                fields.push(csv_value(row.result.get(col)));
            }
            fields.push(csv_value(Some(&Json::Num(row.wall_ms))));
            fields.push(csv_escape(&row.result_hash));
            fields.push(csv_escape(&self.spec_hash));
            fields.push(csv_escape(&self.git));
            fields.push(csv_escape(&row.reason));
            out.push_str(&fields.join(","));
            out.push('\n');
        }
        out
    }

    /// The JSON document: header + summary + one flat object per cell
    /// (result keys merged with identity/provenance keys; `Json`'s
    /// sorted-map writer keeps the byte order deterministic).
    pub fn to_json(&self) -> Json {
        let mut doc = Json::obj();
        doc.set("campaign", Json::Str(self.campaign.clone()));
        doc.set("spec_hash", Json::Str(self.spec_hash.clone()));
        doc.set("git", Json::Str(self.git.clone()));
        doc.set("version", Json::Num(MATRIX_VERSION));
        let mut summary = Json::obj();
        summary.set("total", Json::Num(self.rows.len() as f64));
        summary.set("done", Json::Num(self.count("done") as f64));
        summary.set("failed", Json::Num(self.count("failed") as f64));
        summary.set("pending", Json::Num(self.count("pending") as f64));
        doc.set("summary", summary);
        let cells: Vec<Json> = self
            .rows
            .iter()
            .map(|row| {
                let mut j = row.result.clone();
                j.set("cell", Json::Str(row.cell.id()));
                j.set("model", Json::Str(row.cell.model.clone()));
                j.set("scheme", Json::Str(row.cell.scheme.clone()));
                j.set("workers", Json::Num(row.cell.workers as f64));
                j.set("strategies", Json::Str(row.cell.strategies.clone()));
                j.set("inject", Json::Str(row.cell.inject.clone()));
                j.set("replay_mode", Json::Str(row.cell.mode.name().to_string()));
                j.set("status", Json::Str(row.status.clone()));
                j.set("wall_ms", Json::Num(row.wall_ms));
                j.set("result_hash", Json::Str(row.result_hash.clone()));
                if !row.reason.is_empty() {
                    j.set("reason", Json::Str(row.reason.clone()));
                }
                j
            })
            .collect();
        doc.set("cells", Json::Arr(cells));
        doc
    }

    /// Write `matrix.csv` + `matrix.json` into `dir`; returns their
    /// paths `(csv, json)`.
    pub fn write(&self, dir: &Path) -> Result<(PathBuf, PathBuf), String> {
        let csv = dir.join("matrix.csv");
        let json = dir.join("matrix.json");
        std::fs::write(&csv, self.to_csv())
            .map_err(|e| format!("cannot write {}: {e}", csv.display()))?;
        std::fs::write(&json, self.to_json().to_string_pretty())
            .map_err(|e| format!("cannot write {}: {e}", json.display()))?;
        Ok((csv, json))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::queue::JournalState;
    use crate::campaign::spec::CampaignSpec;

    #[test]
    fn pending_and_done_rows_share_one_schema() {
        let spec = CampaignSpec::parse("models = resnet50\nworkers = 2, 4").unwrap();
        let cells = spec.expand();
        let mut state = JournalState {
            campaign: "t".into(),
            spec_hash: spec.hash(),
            ..JournalState::default()
        };
        let mut result = Json::obj();
        result.set("iteration_us", Json::Num(1000.0));
        result.set("executor", Json::Str("local".into()));
        state.cells.insert(
            cells[0].id(),
            CellState::Done { result_hash: "h".into(), wall_ms: 2.0, result },
        );
        let m = Matrix::from_state(&state, &cells, "deadbeef");
        assert_eq!(m.count("done"), 1);
        assert_eq!(m.count("pending"), 1);
        let csv = m.to_csv();
        let header_cols = csv.lines().next().unwrap().split(',').count();
        for line in csv.lines().skip(1) {
            assert_eq!(line.split(',').count(), header_cols, "ragged row: {line}");
        }
        let doc = m.to_json();
        assert_eq!(doc.f64("version"), MATRIX_VERSION);
        assert_eq!(doc.get("summary").unwrap().f64("total"), 2.0);
    }

    #[test]
    fn csv_escaping() {
        assert_eq!(csv_escape("plain"), "plain");
        assert_eq!(csv_escape("a,b"), "\"a,b\"");
        assert_eq!(csv_escape("say \"hi\""), "\"say \"\"hi\"\"\"");
        assert_eq!(csv_value(Some(&Json::Num(42.0))), "42");
        assert_eq!(csv_value(Some(&Json::Null)), "");
        assert_eq!(csv_value(None), "");
    }
}
