//! The parallel campaign executor.
//!
//! Cells are dispatched onto the shared [`FixedPool`]; each cell is
//! executed either in-process (testbed profile → fault injection →
//! replay → diagnose → optimize, per the spec's settings) or — for
//! cells a live daemon can answer (analytic, exact-mode, fault-free,
//! strategy-free) — against a `dpro serve` endpoint through the shared
//! HTTP client. Every state transition is journaled before/after
//! execution ([`super::queue`]), the matrix is assembled *only* from
//! the journal, and per-cell results carry no wall-clock inputs (the
//! optimizer runs round-bounded, timestamps live outside the hashed
//! result), so kill-and-resume reproduces an uninterrupted run
//! bit-for-bit — the property `rust/tests/campaign.rs` pins.
//!
//! Each cell also records executor self-telemetry ([`CellTelemetry`]):
//! per-phase wall times and the queue depth at dispatch, merged into
//! the result row *after* its hash is computed (and zeroed under
//! [`RunOpts::fixed_wall_ms`]) so observability never perturbs the
//! resume property. Cells run under a `campaign.cell` span when
//! self-tracing ([`crate::obs`]) is enabled.

use super::matrix::{Matrix, RESULT_COLUMNS};
use super::queue::{CellState, Journal, JournalState, JOURNAL_FILE};
use super::spec::{CampaignSpec, Cell, Source, NONE};
use crate::baselines;
use crate::config::{CommScheme, JobSpec};
use crate::diagnosis::{Diagnoser, DiagnosisReport};
use crate::fault;
use crate::graph::build::{build_global_nameless, AnalyticCost};
use crate::graph::dfg::OpKind;
use crate::optimizer::{optimize, SearchOpts};
use crate::profiler;
use crate::replay::tiered::{ReplayMode, TieredReplayer};
use crate::replay::Replayer;
use crate::serve::http::Client;
use crate::serve::fnv1a;
use crate::testbed::{run as tb_run, TestbedOpts};
use crate::trace::validate::TraceReport;
use crate::util::json::Json;
use crate::util::pool::FixedPool;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Campaign failure, classified per the repo's exit-code contract.
#[derive(Debug)]
pub enum CampaignError {
    /// Caller error (malformed spec, empty expansion, journal already
    /// present on a fresh run) — the CLI's exit-2 class.
    Arg(String),
    /// Unusable persistent state or environment (unreadable/mismatched
    /// journal, unresolvable endpoint, unwritable output) — exit 3.
    Data(String),
}

impl CampaignError {
    /// The message, regardless of class.
    pub fn message(&self) -> &str {
        match self {
            CampaignError::Arg(m) | CampaignError::Data(m) => m,
        }
    }

    /// The process exit code for this class.
    pub fn exit_code(&self) -> i32 {
        match self {
            CampaignError::Arg(_) => 2,
            CampaignError::Data(_) => 3,
        }
    }
}

/// Fresh run vs. continuation of an existing journal.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LaunchMode {
    /// `campaign run`: the output directory must not already hold a
    /// journal (refuses rather than clobbering history).
    Fresh,
    /// `campaign resume`: the journal must exist and match the spec
    /// hash; `done` cells are never re-executed.
    Resume,
}

/// Executor options (CLI flags + the determinism seams tests/benches
/// use).
#[derive(Clone, Debug)]
pub struct RunOpts {
    /// Output directory (journal + matrix + canonical spec copy).
    pub out_dir: PathBuf,
    /// Pool width.
    pub jobs: usize,
    /// `host:port` of a live `dpro serve` daemon; eligible cells are
    /// executed remotely, the rest fall back to in-process.
    pub endpoint: Option<String>,
    /// On resume, also retry cells that previously `failed`.
    pub retry_failed: bool,
    /// Stop dispatching new cells after this many seconds; already
    /// dispatched cells finish and undispatched ones stay `pending`
    /// (the matrix says so honestly).
    pub budget_s: Option<f64>,
    /// Provenance override for `git describe` (tests pin this so
    /// matrices compare bit-for-bit across builds).
    pub git: Option<String>,
    /// Record this wall time for every cell instead of measuring
    /// (determinism seam — wall clocks don't reproduce).
    pub fixed_wall_ms: Option<f64>,
    /// Crash simulation: once this many cells have completed, stop
    /// executing — the in-flight cell's `running` line is left dangling
    /// exactly as a SIGKILL would leave it. Test-only.
    pub kill_after_done: Option<usize>,
    /// Suppress per-cell progress lines on stderr.
    pub quiet: bool,
}

impl Default for RunOpts {
    fn default() -> Self {
        RunOpts {
            out_dir: PathBuf::from("campaign_out"),
            jobs: 4,
            endpoint: None,
            retry_failed: false,
            budget_s: None,
            git: None,
            fixed_wall_ms: None,
            kill_after_done: None,
            quiet: false,
        }
    }
}

/// What a campaign invocation did.
#[derive(Debug)]
pub struct Outcome {
    /// Cells in the expanded matrix.
    pub total: usize,
    /// Cells executed by *this* invocation.
    pub executed: usize,
    /// `done` cells reused from the journal (never re-run).
    pub reused: usize,
    /// Final `done` count.
    pub done: usize,
    /// Final `failed` count.
    pub failed: usize,
    /// Cells still pending (budget-truncated or killed).
    pub pending: usize,
    /// True when the crash simulation fired (no matrix is written).
    pub killed: bool,
    /// Written matrix paths (`None` when killed).
    pub csv: Option<PathBuf>,
    /// JSON matrix path.
    pub json: Option<PathBuf>,
}

/// `git describe --always --dirty` of the working tree, or `"unknown"`
/// outside a git checkout.
pub fn git_describe() -> String {
    std::process::Command::new("git")
        .args(["describe", "--always", "--dirty"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".into())
}

/// Reduce the journal in `out_dir` for `spec` (the `status` command and
/// the post-run matrix assembly share this path).
pub fn load_state(spec: &CampaignSpec, out_dir: &Path) -> Result<JournalState, CampaignError> {
    Journal::load(out_dir, Some(&spec.hash())).map_err(CampaignError::Data)
}

/// Execute (or continue) a campaign. See [`RunOpts`] for the knobs; the
/// journal in `opts.out_dir` is the single source of truth and the
/// matrix is recomputed from it after the pool drains.
pub fn run(spec: &CampaignSpec, mode: LaunchMode, opts: &RunOpts) -> Result<Outcome, CampaignError> {
    let cells = spec.expand();
    if cells.is_empty() {
        return Err(CampaignError::Arg(
            "spec expands to zero cells (include/exclude filtered everything out)".into(),
        ));
    }
    if opts.jobs == 0 {
        return Err(CampaignError::Arg("--jobs must be at least 1".into()));
    }
    let spec_hash = spec.hash();

    // a configured endpoint must answer before we touch the journal —
    // a dead daemon should not leave a fresh header-only journal behind
    if let Some(addr) = &opts.endpoint {
        let mut c = Client::new(addr);
        match c.call("GET", "/healthz", None) {
            Ok((200, _)) => {}
            Ok((status, body)) => {
                return Err(CampaignError::Data(format!(
                    "endpoint {addr} unhealthy: /healthz returned {status}: {body}"
                )))
            }
            Err(e) => {
                return Err(CampaignError::Data(format!("unresolvable endpoint {addr}: {e}")))
            }
        }
    }

    // journal: create fresh or open + reduce the existing one
    let (journal, prior) = match mode {
        LaunchMode::Fresh => {
            if opts.out_dir.join(JOURNAL_FILE).exists() {
                return Err(CampaignError::Arg(format!(
                    "{} already holds a journal; use `dpro campaign resume` to continue it \
                     or a fresh --out directory",
                    opts.out_dir.display()
                )));
            }
            let j = Journal::create(&opts.out_dir, &spec.name, &spec_hash)
                .map_err(CampaignError::Data)?;
            (j, JournalState::default())
        }
        LaunchMode::Resume => {
            let state = load_state(spec, &opts.out_dir)?;
            let j = Journal::open(&opts.out_dir).map_err(CampaignError::Data)?;
            (j, state)
        }
    };
    // canonical spec copy beside the journal (same bytes every time —
    // pure provenance, not consulted on resume)
    let spec_path = opts.out_dir.join("spec.txt");
    std::fs::write(&spec_path, spec.to_string())
        .map_err(|e| CampaignError::Data(format!("cannot write {}: {e}", spec_path.display())))?;

    let todo: Vec<Cell> = cells
        .iter()
        .filter(|c| match prior.cells.get(&c.id()) {
            Some(CellState::Done { .. }) => false,
            Some(CellState::Failed { .. }) => opts.retry_failed,
            Some(CellState::Running) | None => true,
        })
        .cloned()
        .collect();
    let reused = cells.len() - todo.len();

    let journal = Arc::new(journal);
    let sspec = Arc::new(spec.clone());
    let killed = Arc::new(AtomicBool::new(false));
    let done_counter = Arc::new(AtomicUsize::new(0));
    let executed = Arc::new(AtomicUsize::new(0));
    let io_errors: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
    let deadline = opts.budget_s.map(|s| Instant::now() + Duration::from_secs_f64(s.max(0.0)));

    {
        let pool = FixedPool::new(opts.jobs);
        let pending = pool.pending_handle();
        for cell in todo {
            let pending = Arc::clone(&pending);
            let journal = Arc::clone(&journal);
            let sspec = Arc::clone(&sspec);
            let killed = Arc::clone(&killed);
            let done_counter = Arc::clone(&done_counter);
            let executed = Arc::clone(&executed);
            let io_errors = Arc::clone(&io_errors);
            let endpoint = opts.endpoint.clone();
            let fixed_wall_ms = opts.fixed_wall_ms;
            let kill_after_done = opts.kill_after_done;
            let quiet = opts.quiet;
            pool.execute(move || {
                if killed.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(d) = deadline {
                    if Instant::now() >= d {
                        return; // honest `pending` row, not a silent drop
                    }
                }
                let id = cell.id();
                if let Err(e) = journal.running(&id) {
                    io_errors.lock().unwrap_or_else(std::sync::PoisonError::into_inner).push(e);
                    return;
                }
                // crash simulation: die *between* the running line and
                // the result, exactly where a SIGKILL hurts most
                if let Some(k) = kill_after_done {
                    if done_counter.load(Ordering::SeqCst) >= k {
                        killed.store(true, Ordering::SeqCst);
                        return;
                    }
                }
                executed.fetch_add(1, Ordering::SeqCst);
                // cells queued behind this one when it started — a
                // telemetry column, zeroed (like the phase timings)
                // under the fixed_wall_ms determinism seam
                let queue_depth = pending.load(Ordering::SeqCst).saturating_sub(1) as f64;
                let cell_span = crate::obs::span("campaign.cell", crate::obs::SpanKind::Work);
                let t0 = Instant::now();
                let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    execute_cell(&sspec, &cell, endpoint.as_deref())
                }))
                .unwrap_or_else(|p| {
                    let what = p
                        .downcast_ref::<&str>()
                        .map(|s| (*s).to_string())
                        .or_else(|| p.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "panic".into());
                    Err(format!("panicked: {what}"))
                });
                drop(cell_span);
                let wall_ms = fixed_wall_ms.unwrap_or_else(|| t0.elapsed().as_secs_f64() * 1e3);
                let append = match outcome {
                    Ok((mut result, tele)) => {
                        // hash BEFORE merging telemetry: tele values are
                        // wall-clock-derived and must never enter
                        // result_hash (the bit-for-bit resume property)
                        let hash = format!("{:016x}", fnv1a(result.to_string().bytes()));
                        let zeroed = fixed_wall_ms.is_some();
                        let t = |v: f64| Json::Num(if zeroed { 0.0 } else { v });
                        result.set("tele_replay_us", t(tele.replay_us));
                        result.set("tele_diagnose_us", t(tele.diagnose_us));
                        result.set("tele_optimize_us", t(tele.optimize_us));
                        result.set("tele_queue_depth", t(queue_depth));
                        if !quiet {
                            eprintln!("campaign: done {id} ({:.0} us)", result.f64("iteration_us"));
                        }
                        let r = journal.done(&id, &hash, wall_ms, result);
                        done_counter.fetch_add(1, Ordering::SeqCst);
                        r
                    }
                    Err(reason) => {
                        if !quiet {
                            eprintln!("campaign: FAILED {id}: {reason}");
                        }
                        journal.failed(&id, &reason)
                    }
                };
                if let Err(e) = append {
                    io_errors.lock().unwrap_or_else(std::sync::PoisonError::into_inner).push(e);
                }
            });
        }
        // pool Drop joins all workers
    }

    let io_errors = io_errors.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    if let Some(e) = io_errors.first() {
        return Err(CampaignError::Data(format!("journal write failed: {e}")));
    }
    drop(io_errors);

    let state = load_state(spec, &opts.out_dir)?;
    let was_killed = killed.load(Ordering::SeqCst);
    let done = state.count("done");
    let failed = state.count("failed");
    let mut outcome = Outcome {
        total: cells.len(),
        executed: executed.load(Ordering::SeqCst),
        reused,
        done,
        failed,
        pending: cells.len() - done - failed,
        killed: was_killed,
        csv: None,
        json: None,
    };
    if was_killed {
        // a real crash writes no matrix either; resume will
        return Ok(outcome);
    }
    let git = opts.git.clone().unwrap_or_else(git_describe);
    let matrix = Matrix::from_state(&state, &cells, &git);
    let (csv, json) = matrix.write(&opts.out_dir).map_err(CampaignError::Data)?;
    outcome.csv = Some(csv);
    outcome.json = Some(json);
    Ok(outcome)
}

/// Build the per-cell [`JobSpec`] the way the CLI does: standard spec,
/// resolved worker count, scheme re-parsed against the resolved cluster
/// shape, deployed-default plan.
fn build_job(spec: &CampaignSpec, cell: &Cell) -> Result<JobSpec, String> {
    if crate::models::by_name(&cell.model, 1).is_none() {
        return Err(format!("unknown model {:?}", cell.model));
    }
    let cluster = crate::config::ClusterSpec::default_16(spec.transport);
    if CommScheme::parse(&cell.scheme, &cluster).is_none() {
        return Err(format!("unknown scheme {:?}", cell.scheme));
    }
    let mut j = JobSpec::standard(&cell.model, &cell.scheme, spec.transport);
    j.cluster.n_workers = cell.workers;
    j.scheme = CommScheme::parse(&cell.scheme, &j.cluster)
        .ok_or_else(|| format!("scheme {:?} rejects {} workers", cell.scheme, cell.workers))?;
    Ok(baselines::deployed_default(&j))
}

/// A result row with every schema column present (inapplicable ones
/// `null`), so the matrix header never varies with spec contents.
fn empty_result() -> Json {
    let mut r = Json::obj();
    for col in RESULT_COLUMNS {
        r.set(col, Json::Null);
    }
    r
}

/// Per-cell executor self-telemetry: wall time spent in each pipeline
/// phase, measured around the phase calls. The dispatcher merges these
/// into the result row **after** the result hash is computed — and
/// zeroes them under [`RunOpts::fixed_wall_ms`] — so telemetry never
/// enters `result_hash` and kill-and-resume stays bit-for-bit
/// (`rust/tests/campaign.rs`).
#[derive(Clone, Copy, Debug, Default)]
pub struct CellTelemetry {
    /// Wall µs building + replaying (testbed profiling included).
    pub replay_us: f64,
    /// Wall µs in diagnosis.
    pub diagnose_us: f64,
    /// Wall µs in optimizer search.
    pub optimize_us: f64,
}

/// Whether a live daemon can execute this cell: the serve API registers
/// analytic jobs and replays them exactly — faults, testbed traces,
/// tiered mode and optimizer mutations stay in-process (an `optimize`
/// over HTTP would mutate a session other clients share).
fn serve_eligible(spec: &CampaignSpec, cell: &Cell) -> bool {
    spec.source == Source::Analytic
        && cell.mode == ReplayMode::Exact
        && cell.inject == NONE
        && cell.strategies == NONE
}

/// Execute one cell, locally or against the endpoint.
fn execute_cell(
    spec: &CampaignSpec,
    cell: &Cell,
    endpoint: Option<&str>,
) -> Result<(Json, CellTelemetry), String> {
    match endpoint {
        Some(addr) if serve_eligible(spec, cell) => execute_serve(spec, cell, addr),
        _ => execute_local(spec, cell),
    }
}

/// Fold the shared diagnosis columns into `r`.
fn apply_diagnosis(r: &mut Json, rep: &DiagnosisReport) {
    r.set("path_comp_us", Json::Num(rep.blame.path.comp_us));
    r.set("path_comm_us", Json::Num(rep.blame.path.comm_us));
    if let Some(b) = rep.bottlenecks.first() {
        r.set("top_bottleneck", Json::Str(format!("{}:{}", b.kind.name(), b.subject)));
    }
    // auto_queries()[0] is always the perfect-overlap counterfactual
    if let Some(w) = rep.whatif.first() {
        r.set("perfect_overlap_speedup", Json::Num(w.speedup));
    }
}

/// In-process execution: the full pipeline the CLI commands compose,
/// driven by the spec's settings.
fn execute_local(spec: &CampaignSpec, cell: &Cell) -> Result<(Json, CellTelemetry), String> {
    let jspec = build_job(spec, cell)?;
    let mut r = empty_result();
    r.set("executor", Json::Str("local".into()));
    let mut tele = CellTelemetry::default();

    let mut diagnoser: Option<Diagnoser> = None;
    let t_replay = Instant::now();
    let _replay_span = crate::obs::span("campaign.replay", crate::obs::SpanKind::Work);
    match spec.source {
        Source::Testbed => {
            let tb = tb_run(
                &jspec,
                &TestbedOpts { iterations: spec.iters, seed: spec.seed, stragglers: Vec::new() },
            );
            let mut trace = tb.trace;
            let mut report = TraceReport::default();
            if cell.inject != NONE {
                // the spec's `+`-joined scenario is the fault grammar's
                // comma-joined list
                let faults = fault::parse_faults(&cell.inject.replace('+', ","))?;
                fault::apply_all(&faults, &mut trace, &mut report);
            }
            let est = profiler::estimate_with_mode(&jspec, &trace, true, cell.mode);
            r.set("iteration_us", Json::Num(est.iteration_us()));
            r.set("fw_us", Json::Num(est.fw_us()));
            r.set("bw_us", Json::Num(est.bw_us()));
            r.set("est_peak_mem_bytes", Json::Num(est.peak_memory(&jspec)));
            r.set("ops", Json::Num(est.profiled_ops as f64));
            let (mode_used, demoted) = match &est.tier {
                Some(t) => (t.mode_used.clone(), !t.demoted.is_empty()),
                None => (cell.mode.name().to_string(), false),
            };
            r.set("mode_used", Json::Str(mode_used));
            r.set("demoted", Json::Bool(demoted));
            r.set("trace_warnings", Json::Num(report.diagnostics.len() as f64));
            if spec.diagnose {
                diagnoser = Some(Diagnoser::from_trace(jspec.clone(), &trace, report));
            }
        }
        Source::Analytic => {
            let g = build_global_nameless(&jspec, &AnalyticCost::new(&jspec));
            let (iteration, fw, bw, peak, mode_used, demoted) = match cell.mode {
                ReplayMode::Exact => {
                    let mut eng = Replayer::new(&g);
                    let res = eng.replay(&g);
                    (
                        res.iteration_time,
                        res.kind_time(&g, 0, OpKind::Forward),
                        res.kind_time(&g, 0, OpKind::Backward),
                        crate::replay::estimate_peak_memory(&jspec, &g, res),
                        "exact".to_string(),
                        false,
                    )
                }
                ReplayMode::Tiered => {
                    let mut eng = TieredReplayer::new(&g, &jspec);
                    let res = eng.replay(&g);
                    let iteration = res.iteration_time;
                    let fw = res.kind_time(&g, 0, OpKind::Forward);
                    let bw = res.kind_time(&g, 0, OpKind::Backward);
                    let peak = crate::replay::estimate_peak_memory(&jspec, &g, res);
                    let rep = eng.report();
                    (iteration, fw, bw, peak, rep.mode_used.clone(), !rep.demoted.is_empty())
                }
            };
            r.set("iteration_us", Json::Num(iteration));
            r.set("fw_us", Json::Num(fw));
            r.set("bw_us", Json::Num(bw));
            r.set("est_peak_mem_bytes", Json::Num(peak));
            r.set("ops", Json::Num(g.dfg.len() as f64));
            r.set("mode_used", Json::Str(mode_used));
            r.set("demoted", Json::Bool(demoted));
            if spec.diagnose {
                diagnoser = Some(Diagnoser::new(jspec.clone()));
            }
        }
    }
    drop(_replay_span);
    tele.replay_us = t_replay.elapsed().as_secs_f64() * 1e6;

    if let Some(mut d) = diagnoser {
        let _span = crate::obs::span("campaign.diagnose", crate::obs::SpanKind::Work);
        let t0 = Instant::now();
        let queries = d.auto_queries();
        let rep = d.report(&queries, 3);
        apply_diagnosis(&mut r, &rep);
        tele.diagnose_us = t0.elapsed().as_secs_f64() * 1e6;
    }

    if cell.strategies != NONE {
        let _span = crate::obs::span("campaign.optimize", crate::obs::SpanKind::Work);
        let t0 = Instant::now();
        // round-bounded, never wall-bounded: campaign results must not
        // depend on machine speed (the resume property compares bytes)
        let so = SearchOpts {
            strategies: Some(cell.strategies.replace('+', ",")),
            max_rounds: spec.rounds,
            converge_rounds: spec.rounds,
            budget_wall_s: f64::INFINITY,
            ..SearchOpts::default()
        };
        let out = optimize(&jspec, &so);
        r.set("opt_us", Json::Num(out.est_iteration_us));
        r.set("opt_speedup", Json::Num(out.speedup()));
        tele.optimize_us = t0.elapsed().as_secs_f64() * 1e6;
    }
    Ok((r, tele))
}

/// Remote execution against a `dpro serve` daemon, through the shared
/// [`Client`] JSON helpers.
fn execute_serve(
    spec: &CampaignSpec,
    cell: &Cell,
    addr: &str,
) -> Result<(Json, CellTelemetry), String> {
    let mut tele = CellTelemetry::default();
    let t_replay = Instant::now();
    let replay_span = crate::obs::span("campaign.replay", crate::obs::SpanKind::Net);
    let mut c = Client::new(addr);
    let mut job = Json::obj();
    job.set("model", Json::Str(cell.model.clone()));
    job.set("scheme", Json::Str(cell.scheme.clone()));
    job.set("transport", Json::Str(spec.transport.name().to_lowercase()));
    job.set("workers", Json::Num(cell.workers as f64));
    let mut body = Json::obj();
    body.set("job", job);
    let reg = c.post_json("/jobs", &body.to_string())?;
    let id = reg.str("job").to_string();

    let replay = c.get_json(&format!("/jobs/{id}/replay"))?;
    drop(replay_span);
    tele.replay_us = t_replay.elapsed().as_secs_f64() * 1e6;
    let mut r = empty_result();
    r.set("executor", Json::Str("serve".into()));
    for key in ["iteration_us", "fw_us", "bw_us", "est_peak_mem_bytes", "ops"] {
        r.set(key, Json::Num(replay.f64(key)));
    }
    r.set("mode_used", Json::Str("exact".into()));
    r.set("demoted", Json::Bool(false));

    if spec.diagnose {
        let _span = crate::obs::span("campaign.diagnose", crate::obs::SpanKind::Net);
        let t0 = Instant::now();
        let diag = c.get_json(&format!("/jobs/{id}/diagnose"))?;
        let path = diag
            .get("blame")
            .and_then(|b| b.get("path"))
            .ok_or("diagnose response missing blame.path")?;
        r.set("path_comp_us", Json::Num(path.f64("comp_us")));
        r.set("path_comm_us", Json::Num(path.f64("comm_us")));
        if let Some(b) = diag.get("bottlenecks").and_then(Json::as_arr).and_then(<[Json]>::first) {
            r.set("top_bottleneck", Json::Str(format!("{}:{}", b.str("kind"), b.str("subject"))));
        }
        if let Some(w) = diag.get("whatif").and_then(Json::as_arr).and_then(<[Json]>::first) {
            r.set("perfect_overlap_speedup", Json::Num(w.f64("speedup")));
        }
        tele.diagnose_us = t0.elapsed().as_secs_f64() * 1e6;
    }
    Ok((r, tele))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("dpro_run_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn small_spec() -> CampaignSpec {
        CampaignSpec::parse(
            "name = unit\nmodels = resnet50\nschemes = horovod\nworkers = 2\n\
             source = analytic\nreplay-mode = exact, tiered",
        )
        .unwrap()
    }

    #[test]
    fn fresh_run_writes_matrix_and_refuses_rerun() {
        let dir = tmp("fresh");
        let spec = small_spec();
        let opts = RunOpts {
            out_dir: dir.clone(),
            jobs: 2,
            git: Some("test".into()),
            fixed_wall_ms: Some(1.0),
            quiet: true,
            ..RunOpts::default()
        };
        let out = run(&spec, LaunchMode::Fresh, &opts).unwrap();
        assert_eq!(out.total, 2);
        assert_eq!(out.done, 2);
        assert_eq!(out.failed, 0);
        assert!(out.csv.as_ref().unwrap().exists());
        // exact and tiered must agree bit-for-bit (the PR-7 contract)
        let state = load_state(&spec, &dir).unwrap();
        let iters: Vec<String> = state
            .cells
            .values()
            .map(|s| match s {
                CellState::Done { result, .. } => Json::Num(result.f64("iteration_us")).to_string(),
                other => panic!("{other:?}"),
            })
            .collect();
        assert_eq!(iters[0], iters[1]);
        // a second Fresh run on the same dir is an Arg error
        match run(&spec, LaunchMode::Fresh, &opts) {
            Err(CampaignError::Arg(m)) => assert!(m.contains("resume"), "{m}"),
            other => panic!("expected Arg error, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resume_without_journal_is_data_error() {
        let dir = tmp("nojournal");
        let spec = small_spec();
        let opts = RunOpts { out_dir: dir.clone(), quiet: true, ..RunOpts::default() };
        match run(&spec, LaunchMode::Resume, &opts) {
            Err(CampaignError::Data(_)) => {}
            other => panic!("expected Data error, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unresolvable_endpoint_is_data_error() {
        let dir = tmp("endpoint");
        let spec = small_spec();
        let opts = RunOpts {
            out_dir: dir.clone(),
            endpoint: Some("127.0.0.1:1".into()),
            quiet: true,
            ..RunOpts::default()
        };
        match run(&spec, LaunchMode::Fresh, &opts) {
            Err(CampaignError::Data(m)) => assert!(m.contains("endpoint"), "{m}"),
            other => panic!("expected Data error, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn zero_cells_is_arg_error() {
        let mut spec = small_spec();
        spec.include = vec![super::super::spec::Filter {
            clauses: vec![("workers".into(), "999".into())],
        }];
        // hand-built unreachable include (parse would reject it; the
        // executor must still refuse to run an empty matrix)
        let opts = RunOpts { out_dir: tmp("zero"), quiet: true, ..RunOpts::default() };
        match run(&spec, LaunchMode::Fresh, &opts) {
            Err(CampaignError::Arg(m)) => assert!(m.contains("zero cells"), "{m}"),
            other => panic!("expected Arg error, got {other:?}"),
        }
    }
}
