//! The persistent, crash-safe work queue behind `dpro campaign`.
//!
//! One append-only journal file (`journal.jsonl`) records every cell
//! state transition as a single JSON line: a header pinning the
//! campaign name + spec hash, then `running` / `done` / `failed`
//! events. A cell's current state is the last event for its id, so a
//! crash at any byte offset loses at most the final partial line —
//! [`Journal::load`] tolerates exactly that (a malformed *last* line)
//! and rejects corruption anywhere else. `resume` replays the journal,
//! skips every `done` cell (their results ride along in the `done`
//! event, so no recomputation is ever needed), and re-runs cells left
//! `running` by the crash.
//!
//! Writes go through one mutex-held `write_all` per line, so
//! concurrent pool workers never interleave partial lines.

use crate::util::json::{parse, Json};
use std::collections::BTreeMap;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Journal file name inside the campaign output directory.
pub const JOURNAL_FILE: &str = "journal.jsonl";

/// Journal format version (bumped on incompatible line-schema changes).
pub const JOURNAL_VERSION: f64 = 1.0;

/// A cell's current state, as reduced from the journal.
#[derive(Clone, Debug, PartialEq)]
pub enum CellState {
    /// A `running` line without a later `done`/`failed` — the cell was
    /// in flight when the campaign stopped; resume re-runs it.
    Running,
    /// Finished: the result row (flat JSON object) and its hash.
    Done {
        /// Hash of the timing-independent result fields.
        result_hash: String,
        /// Wall-clock execution time in milliseconds.
        wall_ms: f64,
        /// The full per-cell result object (matrix row source).
        result: Json,
    },
    /// Execution failed; resume retries only with `--retry-failed`.
    Failed {
        /// Human-readable failure reason.
        reason: String,
    },
}

/// The reduction of a journal: last state per cell plus the counters
/// the resumability property test asserts on.
#[derive(Debug, Default)]
pub struct JournalState {
    /// Campaign name from the header.
    pub campaign: String,
    /// Spec hash from the header.
    pub spec_hash: String,
    /// Last state per cell id.
    pub cells: BTreeMap<String, CellState>,
    /// Total `running` lines per cell id (execution attempts).
    pub attempts: BTreeMap<String, usize>,
    /// Number of `running` lines appended for a cell *after* that cell
    /// already had a `done` line — must stay 0 (`resume` never re-runs
    /// a done cell; the property test counts this).
    pub reruns_after_done: usize,
}

impl JournalState {
    /// Count of cells currently in `state` (by discriminant).
    pub fn count(&self, want: &str) -> usize {
        self.cells
            .values()
            .filter(|s| match s {
                CellState::Running => want == "running",
                CellState::Done { .. } => want == "done",
                CellState::Failed { .. } => want == "failed",
            })
            .count()
    }
}

/// Append handle to a campaign journal. Cloneable across pool workers
/// via `Arc`; every line is one atomic `write_all` + flush.
pub struct Journal {
    file: Mutex<std::fs::File>,
    path: PathBuf,
}

fn line_err(path: &Path, lineno: usize, why: impl std::fmt::Display) -> String {
    format!("unreadable journal {}: line {}: {}", path.display(), lineno, why)
}

/// Make the journal safe to append to: complete a valid final line that
/// lost only its newline, truncate an unparseable torn fragment.
fn repair_tail(path: &Path) -> Result<(), String> {
    let bytes = std::fs::read(path)
        .map_err(|e| format!("cannot open journal {}: {e}", path.display()))?;
    if bytes.is_empty() || bytes.ends_with(b"\n") {
        return Ok(());
    }
    let keep = bytes.iter().rposition(|&b| b == b'\n').map_or(0, |i| i + 1);
    let tail_is_json = std::str::from_utf8(&bytes[keep..])
        .ok()
        .is_some_and(|t| parse(t).is_ok());
    let mut file = std::fs::OpenOptions::new()
        .append(true)
        .open(path)
        .map_err(|e| format!("cannot open journal {}: {e}", path.display()))?;
    if tail_is_json {
        // the event was fully written, only the newline was lost
        file.write_all(b"\n")
    } else {
        file.set_len(keep as u64)
    }
    .map_err(|e| format!("cannot repair journal tail {}: {e}", path.display()))
}

impl Journal {
    /// Create a fresh journal (fails if one already exists — a fresh
    /// `run` must not silently clobber history; that's what `resume`
    /// is for) and write the header line.
    pub fn create(dir: &Path, campaign: &str, spec_hash: &str) -> Result<Journal, String> {
        std::fs::create_dir_all(dir)
            .map_err(|e| format!("cannot create {}: {e}", dir.display()))?;
        let path = dir.join(JOURNAL_FILE);
        let file = std::fs::OpenOptions::new()
            .create_new(true)
            .append(true)
            .open(&path)
            .map_err(|e| format!("cannot create journal {}: {e}", path.display()))?;
        let journal = Journal { file: Mutex::new(file), path };
        let mut header = Json::obj();
        header.set("campaign", Json::Str(campaign.to_string()));
        header.set("spec_hash", Json::Str(spec_hash.to_string()));
        header.set("version", Json::Num(JOURNAL_VERSION));
        journal.append(&header)?;
        Ok(journal)
    }

    /// Open an existing journal for appending (resume path).
    ///
    /// A crash mid-append can leave the file without a trailing
    /// newline. Appending straight after those bytes would glue the
    /// next event onto the torn fragment and corrupt a *middle* line —
    /// so the tail is repaired first: a trailing fragment that is
    /// complete JSON just gets its newline; an unparseable fragment is
    /// truncated (it carries no recoverable data — [`Journal::load`]
    /// ignores it too).
    pub fn open(dir: &Path) -> Result<Journal, String> {
        let path = dir.join(JOURNAL_FILE);
        repair_tail(&path)?;
        let file = std::fs::OpenOptions::new()
            .append(true)
            .open(&path)
            .map_err(|e| format!("cannot open journal {}: {e}", path.display()))?;
        Ok(Journal { file: Mutex::new(file), path })
    }

    /// Journal file path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    fn append(&self, line: &Json) -> Result<(), String> {
        let mut text = line.to_string();
        text.push('\n');
        let mut file = self.file.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        file.write_all(text.as_bytes())
            .and_then(|()| file.flush())
            .map_err(|e| format!("journal write {}: {e}", self.path.display()))
    }

    /// Record that `cell` started executing.
    pub fn running(&self, cell: &str) -> Result<(), String> {
        let mut j = Json::obj();
        j.set("cell", Json::Str(cell.to_string()));
        j.set("state", Json::Str("running".into()));
        self.append(&j)
    }

    /// Record a finished cell with its result row.
    pub fn done(&self, cell: &str, result_hash: &str, wall_ms: f64, result: Json) -> Result<(), String> {
        let mut j = Json::obj();
        j.set("cell", Json::Str(cell.to_string()));
        j.set("state", Json::Str("done".into()));
        j.set("result_hash", Json::Str(result_hash.to_string()));
        j.set("wall_ms", Json::Num(wall_ms));
        j.set("result", result);
        self.append(&j)
    }

    /// Record a failed cell.
    pub fn failed(&self, cell: &str, reason: &str) -> Result<(), String> {
        let mut j = Json::obj();
        j.set("cell", Json::Str(cell.to_string()));
        j.set("state", Json::Str("failed".into()));
        j.set("reason", Json::Str(reason.to_string()));
        self.append(&j)
    }

    /// Reduce a journal file to per-cell states. `expect_hash`, when
    /// given, must match the header's spec hash — resuming under an
    /// edited spec would silently mix incompatible matrices.
    ///
    /// Tolerated: a malformed **final** line (crash mid-append). Any
    /// other malformed line, a missing/invalid header, or a hash
    /// mismatch is an error (the CLI's exit-3 unusable-data class).
    pub fn load(dir: &Path, expect_hash: Option<&str>) -> Result<JournalState, String> {
        let path = dir.join(JOURNAL_FILE);
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("unreadable journal {}: {e}", path.display()))?;
        let lines: Vec<&str> = text.lines().collect();
        let mut state = JournalState::default();
        if lines.is_empty() {
            return Err(line_err(&path, 1, "empty journal (missing header)"));
        }
        for (i, line) in lines.iter().enumerate() {
            let lineno = i + 1;
            let last = i + 1 == lines.len();
            let parsed = match parse(line) {
                Ok(j) => j,
                // a crash mid-append can truncate only the final line
                Err(_) if last && i > 0 => break,
                Err(e) => return Err(line_err(&path, lineno, format!("bad JSON: {e}"))),
            };
            if i == 0 {
                let version = parsed.get("version").and_then(Json::as_f64);
                if parsed.get("campaign").is_none() || version.is_none() {
                    return Err(line_err(&path, 1, "missing campaign/version header"));
                }
                if version != Some(JOURNAL_VERSION) {
                    return Err(line_err(
                        &path,
                        1,
                        format!("unsupported journal version {:?}", version),
                    ));
                }
                state.campaign = parsed.str("campaign").to_string();
                state.spec_hash = parsed.str("spec_hash").to_string();
                if let Some(expect) = expect_hash {
                    if state.spec_hash != expect {
                        return Err(format!(
                            "journal {} was written by a different spec (journal hash {}, \
                             current spec {}); use a fresh --out directory",
                            path.display(),
                            state.spec_hash,
                            expect
                        ));
                    }
                }
                continue;
            }
            let cell = parsed.str("cell").to_string();
            if cell.is_empty() {
                if last {
                    break; // torn final line that still parsed as JSON
                }
                return Err(line_err(&path, lineno, "missing cell id"));
            }
            let new = match parsed.str("state") {
                "running" => {
                    *state.attempts.entry(cell.clone()).or_insert(0) += 1;
                    if matches!(state.cells.get(&cell), Some(CellState::Done { .. })) {
                        state.reruns_after_done += 1;
                    }
                    CellState::Running
                }
                "done" => CellState::Done {
                    result_hash: parsed.str("result_hash").to_string(),
                    wall_ms: parsed.get("wall_ms").and_then(Json::as_f64).unwrap_or(0.0),
                    result: parsed.get("result").cloned().unwrap_or_else(Json::obj),
                },
                "failed" => CellState::Failed { reason: parsed.str("reason").to_string() },
                other => {
                    if last {
                        break;
                    }
                    return Err(line_err(&path, lineno, format!("unknown state {other:?}")));
                }
            };
            state.cells.insert(cell, new);
        }
        Ok(state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "dpro_queue_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn journal_round_trip() {
        let dir = tmpdir("rt");
        let j = Journal::create(&dir, "demo", "abc123").unwrap();
        j.running("a").unwrap();
        let mut r = Json::obj();
        r.set("iteration_us", Json::Num(42.0));
        j.done("a", "h1", 3.5, r).unwrap();
        j.running("b").unwrap();
        j.failed("b", "boom").unwrap();
        j.running("c").unwrap(); // left running: simulated crash

        let state = Journal::load(&dir, Some("abc123")).unwrap();
        assert_eq!(state.campaign, "demo");
        assert_eq!(state.count("done"), 1);
        assert_eq!(state.count("failed"), 1);
        assert_eq!(state.count("running"), 1);
        assert_eq!(state.reruns_after_done, 0);
        match &state.cells["a"] {
            CellState::Done { result_hash, wall_ms, result } => {
                assert_eq!(result_hash, "h1");
                assert!((wall_ms - 3.5).abs() < 1e-9);
                assert!((result.f64("iteration_us") - 42.0).abs() < 1e-9);
            }
            other => panic!("expected done, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tolerates_torn_final_line_only() {
        let dir = tmpdir("torn");
        let j = Journal::create(&dir, "demo", "h").unwrap();
        j.running("a").unwrap();
        let path = j.path().to_path_buf();
        drop(j);
        // simulate a crash mid-append: truncated JSON on the last line
        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"{\"cell\":\"b\",\"sta").unwrap();
        drop(f);
        let state = Journal::load(&dir, Some("h")).unwrap();
        assert_eq!(state.count("running"), 1);
        assert!(!state.cells.contains_key("b"));

        // but corruption in the MIDDLE is an error
        let text = std::fs::read_to_string(&path).unwrap();
        let fixed = text.replace("{\"cell\":\"a\"", "{broken \"cell\":\"a\"");
        std::fs::write(&path, fixed).unwrap();
        assert!(Journal::load(&dir, Some("h")).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn open_repairs_a_torn_tail_before_appending() {
        let dir = tmpdir("repair");
        let j = Journal::create(&dir, "demo", "h").unwrap();
        j.running("a").unwrap();
        j.done("a", "h1", 1.0, Json::obj()).unwrap();
        let path = j.path().to_path_buf();
        drop(j);
        // an unparseable fragment is truncated, so the next append
        // starts a clean line instead of gluing onto the fragment
        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"{\"cell\":\"b\",\"sta").unwrap();
        drop(f);
        let j = Journal::open(&dir).unwrap();
        j.running("c").unwrap();
        drop(j);
        let state = Journal::load(&dir, Some("h")).unwrap();
        assert_eq!(state.count("done"), 1);
        assert_eq!(state.count("running"), 1);
        assert!(!state.cells.contains_key("b"));
        // a complete final event that lost only its newline is kept:
        // truncating it would throw away a real (possibly done) result
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, text.trim_end_matches('\n')).unwrap();
        let j = Journal::open(&dir).unwrap();
        j.running("d").unwrap();
        drop(j);
        let state = Journal::load(&dir, Some("h")).unwrap();
        assert_eq!(state.count("done"), 1, "the done result must survive repair");
        assert_eq!(state.count("running"), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_hash_mismatch_and_fresh_over_existing() {
        let dir = tmpdir("hash");
        let _ = Journal::create(&dir, "demo", "aaaa").unwrap();
        let err = Journal::load(&dir, Some("bbbb")).unwrap_err();
        assert!(err.contains("different spec"), "{err}");
        // create over an existing journal must refuse
        assert!(Journal::create(&dir, "demo", "aaaa").is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rerun_after_done_is_counted() {
        let dir = tmpdir("rerun");
        let j = Journal::create(&dir, "demo", "h").unwrap();
        j.running("a").unwrap();
        j.done("a", "h1", 1.0, Json::obj()).unwrap();
        j.running("a").unwrap(); // the bug resume must never introduce
        drop(j);
        let state = Journal::load(&dir, Some("h")).unwrap();
        assert_eq!(state.reruns_after_done, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
