//! Campaign runner: declarative scenario sweeps at fleet scale
//! (ROADMAP item 3, `docs/CAMPAIGN.md`).
//!
//! dPRO's evaluation is a matrix — models × schemes × worker counts ×
//! strategy sets × fault scenarios × replay modes. This module turns
//! that matrix into one declarative spec ([`spec`]), a persistent
//! crash-safe work queue ([`queue`]), a parallel executor over the
//! shared thread pool or a live `dpro serve` endpoint ([`run`]), and
//! one CSV + JSON results matrix with per-cell provenance
//! ([`matrix`]). The CLI surface is
//! `dpro campaign run|resume|status --spec <file>`.
//!
//! The central contract, pinned by `rust/tests/campaign.rs`: a
//! campaign killed mid-sweep and resumed produces a matrix
//! **bit-for-bit identical** to an uninterrupted run, with zero
//! re-executed `done` cells. Everything is arranged around that —
//! seeded testbeds, round-bounded optimizer search, journal-only
//! matrix assembly, and explicit provenance seams for the two
//! genuinely nondeterministic inputs (wall time, git describe).

pub mod matrix;
pub mod queue;
pub mod run;
pub mod spec;

pub use matrix::Matrix;
pub use queue::{CellState, Journal, JournalState};
pub use run::{run, CampaignError, LaunchMode, Outcome, RunOpts};
pub use spec::{CampaignSpec, Cell, Filter, Source};
