//! The declarative sweep-spec grammar (`dpro campaign --spec <file>`).
//!
//! A campaign spec names the axes of a scenario matrix — models ×
//! schemes × worker counts × strategy sets × fault scenarios × replay
//! modes — plus a handful of single-valued execution settings, and
//! expands to the cross product of the axes filtered by `include` /
//! `exclude` lines. The format is line-based (`key = value[, value]`,
//! `#` comments), every value is validated against the same registries
//! the CLI flags use, and — like the fault grammar
//! ([`crate::fault::Fault`]) — `Display` emits a canonical form whose
//! re-parse is the identity: `parse(spec.to_string()) == spec`, pinned
//! by the fuzz tests in `rust/tests/campaign.rs`. See `docs/CAMPAIGN.md`
//! for the full grammar.
//!
//! Axis values that themselves have grammars nest with `+` as the list
//! separator (the spec file's `,` separates axis values): a strategy
//! *set* is `op-fuse+tensor-fuse`, a fault *scenario* is
//! `worker-crash:1@1+nic-degrade:0:2@1`. The literal `none` is the
//! empty set on both axes.

use crate::config::{ClusterSpec, CommScheme, Transport, ALL_SCHEMES};
use crate::fault::Fault;
use crate::optimizer::strategy::{parse_strategies, STRATEGY_NAMES};
use crate::replay::tiered::ReplayMode;
use std::fmt::Write as _;

/// The literal meaning "empty set" on the `strategies` / `inject` axes.
pub const NONE: &str = "none";

/// Where a cell's durations come from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Source {
    /// Run the simulated testbed for `iters` iterations (seeded, so
    /// deterministic), producing a measured trace the fault scenarios
    /// degrade and the profiler replays — the `profile → replay` path.
    Testbed,
    /// Build the graph analytically (no trace): the pre-deployment
    /// what-if path, and the only practical one at fleet scale.
    Analytic,
}

impl Source {
    /// Parse a spec value.
    pub fn parse(s: &str) -> Option<Source> {
        match s {
            "testbed" => Some(Source::Testbed),
            "analytic" => Some(Source::Analytic),
            _ => None,
        }
    }

    /// Canonical spec spelling.
    pub fn name(self) -> &'static str {
        match self {
            Source::Testbed => "testbed",
            Source::Analytic => "analytic",
        }
    }
}

/// One expanded point of the sweep matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Cell {
    /// Model template name.
    pub model: String,
    /// Canonical scheme name.
    pub scheme: String,
    /// Worker count.
    pub workers: usize,
    /// Strategy set (`none` or `+`-joined strategy names).
    pub strategies: String,
    /// Fault scenario (`none` or `+`-joined fault specs).
    pub inject: String,
    /// Requested replay engine.
    pub mode: ReplayMode,
}

impl Cell {
    /// The cell's identity — journal key and matrix row id. Axis values
    /// contain no `/`, so the id splits back unambiguously.
    pub fn id(&self) -> String {
        format!(
            "{}/{}/w{}/{}/{}/{}",
            self.model,
            self.scheme,
            self.workers,
            self.strategies,
            self.inject,
            self.mode.name()
        )
    }

    /// The canonical value of one filterable axis (filter matching).
    fn axis(&self, key: &str) -> String {
        match key {
            "model" => self.model.clone(),
            "scheme" => self.scheme.clone(),
            "workers" => self.workers.to_string(),
            "strategies" => self.strategies.clone(),
            "inject" => self.inject.clone(),
            "replay-mode" => self.mode.name().to_string(),
            other => unreachable!("unvalidated filter key {other}"),
        }
    }
}

/// A conjunction of `axis=value` clauses (one `include`/`exclude` line).
/// A cell matches when **every** clause holds.
#[derive(Clone, Debug, PartialEq)]
pub struct Filter {
    /// `(axis key, canonical value)` pairs, in spec order.
    pub clauses: Vec<(String, String)>,
}

impl Filter {
    /// Whether `cell` satisfies every clause.
    pub fn matches(&self, cell: &Cell) -> bool {
        self.clauses.iter().all(|(k, v)| cell.axis(k) == *v)
    }
}

impl std::fmt::Display for Filter {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (i, (k, v)) in self.clauses.iter().enumerate() {
            if i > 0 {
                write!(f, " & ")?;
            }
            write!(f, "{k}={v}")?;
        }
        Ok(())
    }
}

/// A parsed, validated campaign spec. Construct via [`CampaignSpec::parse`]
/// (or field-by-field from code, as the ported benches do); `Display`
/// emits the canonical file form.
#[derive(Clone, Debug, PartialEq)]
pub struct CampaignSpec {
    /// Campaign name (journal/matrix identity, not a cell axis).
    pub name: String,
    /// Model axis.
    pub models: Vec<String>,
    /// Scheme axis (canonical [`ALL_SCHEMES`] names).
    pub schemes: Vec<String>,
    /// Worker-count axis.
    pub workers: Vec<usize>,
    /// Strategy-set axis (`none` or `+`-joined names).
    pub strategies: Vec<String>,
    /// Fault-scenario axis (`none` or `+`-joined fault specs).
    pub inject: Vec<String>,
    /// Replay-mode axis.
    pub modes: Vec<ReplayMode>,
    /// Network transport (setting, not an axis).
    pub transport: Transport,
    /// Duration source (setting).
    pub source: Source,
    /// Run the diagnosis battery per cell (setting).
    pub diagnose: bool,
    /// Testbed iterations per cell (setting).
    pub iters: usize,
    /// Testbed seed (setting) — same seed, same trace, same bytes.
    pub seed: u64,
    /// Optimizer round cap for strategy cells (setting). Campaign cells
    /// are round-bounded, never wall-bounded, so results are
    /// reproducible.
    pub rounds: usize,
    /// When non-empty, a cell must match at least one of these.
    pub include: Vec<Filter>,
    /// A cell matching any of these is dropped (after `include`).
    pub exclude: Vec<Filter>,
}

impl Default for CampaignSpec {
    fn default() -> Self {
        CampaignSpec {
            name: "campaign".into(),
            models: vec!["resnet50".into()],
            schemes: vec!["horovod".into()],
            workers: vec![4],
            strategies: vec![NONE.into()],
            inject: vec![NONE.into()],
            modes: vec![ReplayMode::Exact],
            transport: Transport::Rdma,
            source: Source::Testbed,
            diagnose: false,
            iters: 5,
            seed: 1,
            rounds: 2,
            include: Vec::new(),
            exclude: Vec::new(),
        }
    }
}

/// The axis keys filters may reference, in canonical order.
pub const FILTER_KEYS: [&str; 6] =
    ["model", "scheme", "workers", "strategies", "inject", "replay-mode"];

fn bad(why: impl std::fmt::Display) -> String {
    format!("invalid campaign spec: {why}; see docs/CAMPAIGN.md for the grammar")
}

/// Split an axis value list on `,`, trimming and rejecting empties and
/// duplicates (duplicates would silently skew the cross product — and
/// break the canonical round-trip).
fn split_values(key: &str, raw: &str) -> Result<Vec<String>, String> {
    let mut out: Vec<String> = Vec::new();
    for part in raw.split(',') {
        let v = part.trim();
        if v.is_empty() {
            return Err(bad(format!("empty value in '{key}' list")));
        }
        if out.iter().any(|p| p == v) {
            return Err(bad(format!("duplicate '{key}' value {v:?}")));
        }
        out.push(v.to_string());
    }
    Ok(out)
}

/// Canonicalize one strategy-set value (`none` or `a+b+...`).
fn canon_strategies(v: &str) -> Result<String, String> {
    if v == NONE {
        return Ok(NONE.into());
    }
    let parts: Vec<&str> = v.split('+').map(str::trim).collect();
    if parts.iter().any(|p| p.is_empty()) {
        return Err(bad(format!("empty strategy name in set {v:?}")));
    }
    // reuse the CLI's validator so the error lists the registry
    parse_strategies(&parts.join(","))
        .map_err(|e| bad(format!("strategy set {v:?}: {e}")))?;
    Ok(parts.join("+"))
}

/// Canonicalize one fault-scenario value (`none` or `f1+f2+...`).
fn canon_inject(v: &str) -> Result<String, String> {
    if v == NONE {
        return Ok(NONE.into());
    }
    let mut canon = Vec::new();
    for part in v.split('+') {
        let f = Fault::parse(part).map_err(|e| bad(format!("scenario {v:?}: {e}")))?;
        canon.push(f.to_string());
    }
    Ok(canon.join("+"))
}

impl CampaignSpec {
    /// Parse a spec file's text. Order-independent (all lines are
    /// collected, then the spec is built key by key so e.g. `transport`
    /// applies to scheme validation regardless of line order); every
    /// error is the CLI's exit-2 argument class.
    pub fn parse(text: &str) -> Result<CampaignSpec, String> {
        let mut spec = CampaignSpec::default();
        let mut seen: Vec<String> = Vec::new();
        let mut kv: Vec<(String, String)> = Vec::new();
        let mut includes: Vec<String> = Vec::new();
        let mut excludes: Vec<String> = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| bad(format!("line {}: expected 'key = value'", lineno + 1)))?;
            let (key, value) = (key.trim().to_string(), value.trim().to_string());
            if value.is_empty() {
                return Err(bad(format!("line {}: empty value for '{key}'", lineno + 1)));
            }
            match key.as_str() {
                "include" => includes.push(value),
                "exclude" => excludes.push(value),
                _ => {
                    if seen.contains(&key) {
                        return Err(bad(format!("duplicate key '{key}'")));
                    }
                    seen.push(key.clone());
                    kv.push((key, value));
                }
            }
        }
        let get = |k: &str| kv.iter().find(|(key, _)| key == k).map(|(_, v)| v.as_str());

        // settings first: transport gates scheme validation
        if let Some(v) = get("name") {
            if v.contains('/') || v.contains(char::is_whitespace) {
                return Err(bad(format!("name {v:?} must be a single token without '/'")));
            }
            spec.name = v.to_string();
        }
        if let Some(v) = get("transport") {
            spec.transport = match v {
                "rdma" => Transport::Rdma,
                "tcp" => Transport::Tcp,
                _ => return Err(bad(format!("unknown transport {v:?}; valid: rdma, tcp"))),
            };
        }
        if let Some(v) = get("source") {
            spec.source = Source::parse(v)
                .ok_or_else(|| bad(format!("unknown source {v:?}; valid: testbed, analytic")))?;
        }
        if let Some(v) = get("diagnose") {
            spec.diagnose = match v {
                "on" => true,
                "off" => false,
                _ => return Err(bad(format!("diagnose must be on|off, got {v:?}"))),
            };
        }
        for (key, slot, min) in [
            ("iters", &mut spec.iters as &mut usize, 1usize),
            ("rounds", &mut spec.rounds, 1),
        ] {
            if let Some(v) = get(key) {
                *slot = match v.parse::<usize>() {
                    Ok(n) if n >= min => n,
                    _ => return Err(bad(format!("{key} must be a positive integer, got {v:?}"))),
                };
            }
        }
        if let Some(v) = get("seed") {
            spec.seed = v
                .parse::<u64>()
                .map_err(|_| bad(format!("seed must be a non-negative integer, got {v:?}")))?;
        }

        // axes
        if let Some(v) = get("models") {
            spec.models = split_values("models", v)?;
            for m in &spec.models {
                if crate::models::by_name(m, 1).is_none() {
                    return Err(bad(format!(
                        "unknown model {m:?}; valid: resnet50, vgg16, inception_v3, \
                         bert_base, gpt_mini"
                    )));
                }
            }
        }
        if let Some(v) = get("schemes") {
            let cluster = ClusterSpec::default_16(spec.transport);
            let mut canon = Vec::new();
            for s in split_values("schemes", v)? {
                let parsed = CommScheme::parse(&s, &cluster).ok_or_else(|| {
                    bad(format!("unknown scheme {s:?}; valid: {}", ALL_SCHEMES.join(", ")))
                })?;
                let name = parsed.cli_name().to_string();
                if canon.contains(&name) {
                    return Err(bad(format!("duplicate 'schemes' value {name:?}")));
                }
                canon.push(name);
            }
            spec.schemes = canon;
        }
        if let Some(v) = get("workers") {
            let mut ws = Vec::new();
            for w in split_values("workers", v)? {
                match w.parse::<usize>() {
                    Ok(n) if n >= 1 => ws.push(n),
                    _ => return Err(bad(format!("workers value {w:?} must be a positive integer"))),
                }
            }
            spec.workers = ws;
        }
        if let Some(v) = get("strategies") {
            let mut canon = Vec::new();
            for s in split_values("strategies", v)? {
                let c = canon_strategies(&s)?;
                if canon.contains(&c) {
                    return Err(bad(format!("duplicate 'strategies' value {c:?}")));
                }
                canon.push(c);
            }
            spec.strategies = canon;
        }
        if let Some(v) = get("inject") {
            let mut canon = Vec::new();
            for s in split_values("inject", v)? {
                let c = canon_inject(&s)?;
                if canon.contains(&c) {
                    return Err(bad(format!("duplicate 'inject' value {c:?}")));
                }
                canon.push(c);
            }
            spec.inject = canon;
        }
        if let Some(v) = get("replay-mode") {
            let mut modes = Vec::new();
            for m in split_values("replay-mode", v)? {
                let mode = ReplayMode::parse(&m)
                    .ok_or_else(|| bad(format!("unknown replay-mode {m:?}; valid: exact, tiered")))?;
                if modes.contains(&mode) {
                    return Err(bad(format!("duplicate 'replay-mode' value {m:?}")));
                }
                modes.push(mode);
            }
            spec.modes = modes;
        }

        // unknown keys: rejected, never silently ignored (a typoed axis
        // would otherwise run the default axis without warning)
        for (key, _) in &kv {
            if !matches!(
                key.as_str(),
                "name" | "models" | "schemes" | "workers" | "strategies" | "inject"
                    | "replay-mode" | "transport" | "source" | "diagnose" | "iters" | "seed"
                    | "rounds"
            ) {
                return Err(bad(format!("unknown key '{key}'")));
            }
        }

        for text in includes {
            spec.include.push(spec.parse_filter(&text)?);
        }
        for text in excludes {
            spec.exclude.push(spec.parse_filter(&text)?);
        }

        // faults degrade a measured trace; the analytic path has none
        if spec.source == Source::Analytic && spec.inject.iter().any(|s| s != NONE) {
            return Err(bad(
                "inject scenarios need 'source = testbed' (faults degrade a measured trace)",
            ));
        }
        Ok(spec)
    }

    /// Parse one `include`/`exclude` value: `axis=value [& axis=value]*`.
    /// Clause values are canonicalized and must be members of the
    /// matching axis — a filter that could never match anything is a
    /// typo, not a no-op.
    fn parse_filter(&self, text: &str) -> Result<Filter, String> {
        let mut clauses = Vec::new();
        for clause in text.split('&') {
            let (k, v) = clause
                .split_once('=')
                .ok_or_else(|| bad(format!("filter clause {:?} must be axis=value", clause.trim())))?;
            let (k, v) = (k.trim(), v.trim());
            if !FILTER_KEYS.contains(&k) {
                return Err(bad(format!(
                    "unknown filter axis {k:?}; valid: {}",
                    FILTER_KEYS.join(", ")
                )));
            }
            let canon = match k {
                "strategies" => canon_strategies(v)?,
                "inject" => canon_inject(v)?,
                _ => v.to_string(),
            };
            let member = match k {
                "model" => self.models.contains(&canon),
                "scheme" => self.schemes.contains(&canon),
                "workers" => self.workers.iter().any(|w| w.to_string() == canon),
                "strategies" => self.strategies.contains(&canon),
                "inject" => self.inject.contains(&canon),
                "replay-mode" => self.modes.iter().any(|m| m.name() == canon),
                _ => unreachable!(),
            };
            if !member {
                return Err(bad(format!(
                    "filter value {canon:?} is not on the '{k}' axis"
                )));
            }
            clauses.push((k.to_string(), canon));
        }
        Ok(Filter { clauses })
    }

    /// Load and parse a spec file.
    pub fn load(path: &std::path::Path) -> Result<CampaignSpec, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read spec {}: {e}", path.display()))?;
        CampaignSpec::parse(&text)
    }

    /// Expand the cross product of the axes, in canonical nesting order
    /// (model outermost, replay-mode innermost), then apply `include`
    /// (keep cells matching at least one, when any are given) and
    /// `exclude` (drop cells matching any). The order is deterministic:
    /// the same spec always yields the same cell list.
    pub fn expand(&self) -> Vec<Cell> {
        let mut cells = Vec::new();
        for model in &self.models {
            for scheme in &self.schemes {
                for &workers in &self.workers {
                    for strategies in &self.strategies {
                        for inject in &self.inject {
                            for &mode in &self.modes {
                                let cell = Cell {
                                    model: model.clone(),
                                    scheme: scheme.clone(),
                                    workers,
                                    strategies: strategies.clone(),
                                    inject: inject.clone(),
                                    mode,
                                };
                                let kept = (self.include.is_empty()
                                    || self.include.iter().any(|f| f.matches(&cell)))
                                    && !self.exclude.iter().any(|f| f.matches(&cell));
                                if kept {
                                    cells.push(cell);
                                }
                            }
                        }
                    }
                }
            }
        }
        cells
    }

    /// The unfiltered algebraic product of the axis lengths.
    pub fn product(&self) -> usize {
        self.models.len()
            * self.schemes.len()
            * self.workers.len()
            * self.strategies.len()
            * self.inject.len()
            * self.modes.len()
    }

    /// FNV-1a over the canonical form, as fixed-width hex — the
    /// provenance column and the journal's spec identity.
    pub fn hash(&self) -> String {
        format!("{:016x}", crate::serve::fnv1a(self.to_string().bytes()))
    }
}

impl std::fmt::Display for CampaignSpec {
    /// Canonical spec form: every key explicit, fixed order, `, ` value
    /// separators — the round-trip anchor (`parse(to_string()) == self`).
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        let _ = writeln!(out, "name = {}", self.name);
        let _ = writeln!(out, "models = {}", self.models.join(", "));
        let _ = writeln!(out, "schemes = {}", self.schemes.join(", "));
        let _ = writeln!(
            out,
            "workers = {}",
            self.workers.iter().map(usize::to_string).collect::<Vec<_>>().join(", ")
        );
        let _ = writeln!(out, "strategies = {}", self.strategies.join(", "));
        let _ = writeln!(out, "inject = {}", self.inject.join(", "));
        let _ = writeln!(
            out,
            "replay-mode = {}",
            self.modes.iter().map(|m| m.name().to_string()).collect::<Vec<_>>().join(", ")
        );
        let _ = writeln!(out, "transport = {}", self.transport.name().to_lowercase());
        let _ = writeln!(out, "source = {}", self.source.name());
        let _ = writeln!(out, "diagnose = {}", if self.diagnose { "on" } else { "off" });
        let _ = writeln!(out, "iters = {}", self.iters);
        let _ = writeln!(out, "seed = {}", self.seed);
        let _ = writeln!(out, "rounds = {}", self.rounds);
        for inc in &self.include {
            let _ = writeln!(out, "include = {inc}");
        }
        for exc in &self.exclude {
            let _ = writeln!(out, "exclude = {exc}");
        }
        f.write_str(&out)
    }
}

/// The strategy names a spec may reference (re-exported for docs/tests).
pub fn strategy_names() -> &'static [&'static str] {
    &STRATEGY_NAMES
}

#[cfg(test)]
mod tests {
    use super::*;

    const FULL: &str = "
        # a comment
        name = demo
        models = resnet50, vgg16
        schemes = horovod, byteps
        workers = 4, 8
        strategies = none, op-fuse+tensor-fuse
        inject = none, worker-crash:1@1
        replay-mode = exact, tiered
        transport = rdma
        source = testbed
        diagnose = on
        iters = 3
        seed = 7
        rounds = 2
        exclude = scheme=byteps & workers=8
    ";

    #[test]
    fn parse_and_canonical_round_trip() {
        let spec = CampaignSpec::parse(FULL).unwrap();
        assert_eq!(spec.models, vec!["resnet50", "vgg16"]);
        assert_eq!(spec.product(), 2 * 2 * 2 * 2 * 2 * 2);
        let canon = spec.to_string();
        let again = CampaignSpec::parse(&canon).unwrap();
        assert_eq!(again, spec, "canonical form must re-parse to the same spec");
        assert_eq!(again.to_string(), canon, "display must be a fixed point");
        assert_eq!(again.hash(), spec.hash());
    }

    #[test]
    fn expansion_applies_filters() {
        let spec = CampaignSpec::parse(FULL).unwrap();
        let cells = spec.expand();
        // 64 combos minus byteps&8 (2 models × 2 strategies × 2 inject × 2 modes = 16)
        assert_eq!(cells.len(), 64 - 16);
        assert!(cells.iter().all(|c| !(c.scheme == "byteps" && c.workers == 8)));
        // deterministic order: same spec, same list
        assert_eq!(spec.expand(), cells);
    }

    #[test]
    fn include_keeps_only_matches() {
        let mut spec = CampaignSpec::parse(FULL).unwrap();
        spec.exclude.clear();
        spec.include = vec![spec.parse_filter("model=vgg16").unwrap()];
        assert!(spec.expand().iter().all(|c| c.model == "vgg16"));
        assert_eq!(spec.expand().len(), 32);
    }

    #[test]
    fn rejects_bad_specs() {
        for (text, needle) in [
            ("models = warp9", "unknown model"),
            ("schemes = smoke-signals", "unknown scheme"),
            ("workers = 0", "positive integer"),
            ("strategies = op-fuse+warp", "strategy set"),
            ("inject = gpu-melt:1@1", "scenario"),
            ("replay-mode = psychic", "unknown replay-mode"),
            ("bogus-key = 1", "unknown key"),
            ("models = resnet50, resnet50", "duplicate"),
            ("models = resnet50\nmodels = vgg16", "duplicate key"),
            ("exclude = color=red", "unknown filter axis"),
            ("exclude = model=vgg16", "not on the 'model' axis"),
            ("workers", "expected 'key = value'"),
            ("source = analytic\ninject = worker-crash:1@1", "source = testbed"),
        ] {
            let err = CampaignSpec::parse(text).unwrap_err();
            assert!(err.contains(needle), "{text:?} -> {err}");
        }
    }

    #[test]
    fn scheme_aliases_canonicalize() {
        let a = CampaignSpec::parse("schemes = horovod").unwrap();
        let canon = a.schemes.clone();
        // whatever alias map CommScheme supports, the canonical name is stable
        assert_eq!(canon, vec!["horovod"]);
    }

    #[test]
    fn cell_ids_are_stable_and_unique() {
        let spec = CampaignSpec::parse(FULL).unwrap();
        let cells = spec.expand();
        let mut ids: Vec<String> = cells.iter().map(Cell::id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), cells.len(), "cell ids must be unique");
    }

    #[test]
    fn empty_text_is_the_default_spec() {
        let spec = CampaignSpec::parse("").unwrap();
        assert_eq!(spec, CampaignSpec::default());
        // and the default round-trips too
        assert_eq!(CampaignSpec::parse(&spec.to_string()).unwrap(), spec);
    }
}
