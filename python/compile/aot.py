"""AOT lowering: JAX (L2, calling Pallas L1) → HLO **text** artifacts the
Rust runtime loads via PJRT.

HLO text, NOT `lowered.compile()`/`.serialize()`: jax ≥ 0.5 emits protos
with 64-bit instruction ids which xla_extension 0.5.1 (the version the
`xla` crate binds) rejects; the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Usage:  python -m compile.aot --out-dir ../artifacts [--config mini]

Emits, per config:
  gpt_<cfg>.init.hlo.txt        init(seed)                  -> flat params
  gpt_<cfg>.grad.hlo.txt        grad_step(params, x, y)     -> (loss, grads)
  gpt_<cfg>.apply.hlo.txt       apply_step(params, mom, gr) -> (params, mom)
  gpt_<cfg>.train.hlo.txt       fused single-worker step
  gpt_<cfg>.meta.json           param names/shapes (Rust-side marshalling)
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def param_meta(cfg):
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    leaves, _ = flatten(params)
    names = leaf_names(params)
    return [
        {"name": n, "shape": list(l.shape), "size": int(l.size)}
        for n, l in zip(names, leaves)
    ]


def leaf_names(tree, prefix=""):
    """Stable dotted names matching tree_flatten order (sorted dict keys)."""
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree.keys()):
            out.extend(leaf_names(tree[k], f"{prefix}{k}."))
        return out
    return [prefix.rstrip(".")]


def lower_config(cfg_name: str, out_dir: str):
    cfg = getattr(M.GptConfig, cfg_name)()
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    state = M.init_opt_state(params)
    p_leaves, p_def = flatten(params)
    s_leaves, s_def = flatten(state)
    x = jnp.zeros((cfg.batch_size, cfg.seq_len), jnp.int32)
    y = jnp.zeros((cfg.batch_size, cfg.seq_len), jnp.int32)

    def init_flat(seed):
        p = M.init_params(cfg, jax.random.PRNGKey(seed))
        s = M.init_opt_state(p)
        return tuple(flatten(p)[0] + flatten(s)[0])

    def grad_flat(*args):
        ps = jax.tree_util.tree_unflatten(p_def, args[: len(p_leaves)])
        xx = args[len(p_leaves)]
        yy = args[len(p_leaves) + 1]
        loss, grads = M.grad_step(cfg, ps, xx, yy)
        return tuple([loss] + flatten(grads)[0])

    ns = len(s_leaves)

    def apply_flat(*args):
        n = len(p_leaves)
        ps = jax.tree_util.tree_unflatten(p_def, args[:n])
        st = jax.tree_util.tree_unflatten(s_def, args[n : n + ns])
        gs = jax.tree_util.tree_unflatten(p_def, args[n + ns : n + ns + n])
        np_, nst = M.apply_step(cfg, ps, st, gs)
        return tuple(flatten(np_)[0] + flatten(nst)[0])

    def train_flat(*args):
        n = len(p_leaves)
        ps = jax.tree_util.tree_unflatten(p_def, args[:n])
        st = jax.tree_util.tree_unflatten(s_def, args[n : n + ns])
        xx = args[n + ns]
        yy = args[n + ns + 1]
        loss, np_, nst = M.train_step(cfg, ps, st, xx, yy)
        return tuple([loss] + flatten(np_)[0] + flatten(nst)[0])

    jobs = {
        "init": (init_flat, (jnp.int32(0),)),
        "grad": (grad_flat, tuple(p_leaves) + (x, y)),
        "apply": (apply_flat, tuple(p_leaves) + tuple(s_leaves) + tuple(p_leaves)),
        "train": (train_flat, tuple(p_leaves) + tuple(s_leaves) + (x, y)),
    }
    os.makedirs(out_dir, exist_ok=True)
    for name, (fn, args) in jobs.items():
        path = os.path.join(out_dir, f"gpt_{cfg_name}.{name}.hlo.txt")
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text) / 1e6:.1f} MB)")

    meta = {
        "config": cfg_name,
        "batch_size": cfg.batch_size,
        "seq_len": cfg.seq_len,
        "hidden": cfg.hidden,
        "layers": cfg.layers,
        "heads": cfg.heads,
        "vocab": cfg.vocab,
        "n_state_leaves": len(s_leaves),
        "params": param_meta(cfg),
    }
    with open(os.path.join(out_dir, f"gpt_{cfg_name}.meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    n_params = sum(p["size"] for p in meta["params"])
    print(f"config {cfg_name}: {n_params / 1e6:.1f}M params, {len(meta['params'])} tensors")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--config", action="append", default=None,
                    help="tiny|mini|m100 (repeatable; default tiny+mini)")
    args = ap.parse_args()
    configs = args.config or ["tiny", "mini"]
    for cfg in configs:
        lower_config(cfg, args.out_dir)


if __name__ == "__main__":
    main()
