"""L2: GPT-style decoder LM in JAX, calling the L1 Pallas kernels.

Mirrors `rust/src/models/transformer.rs` (GptConfig) so dPRO can profile
the same architecture the Rust coordinator actually executes via PJRT.

Exports three jittable functions (AOT-lowered by aot.py):
  - init(seed)                         -> params + Adam state
  - grad_step(params, x, y)            -> (loss, grads)       [per worker]
  - apply_step(params, state, grads)   -> (params, state)     [leader]

grad/apply are split so the Rust coordinator can do *data-parallel*
training: workers run grad_step on their shards, the leader averages
gradients (through the simulated network), applies the update once, and
broadcasts. Python never runs at training time.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from compile.kernels import attention as attn_k
from compile.kernels import layernorm as ln_k


@dataclasses.dataclass(frozen=True)
class GptConfig:
    batch_size: int = 4
    seq_len: int = 128
    hidden: int = 384
    layers: int = 6
    heads: int = 6
    vocab: int = 8192

    @staticmethod
    def tiny():
        """Unit-test scale."""
        return GptConfig(batch_size=2, seq_len=32, hidden=64, layers=2, heads=2, vocab=256)

    @staticmethod
    def mini(batch_size=4):
        """~25M params: the config the e2e example trains for hundreds of steps."""
        return GptConfig(batch_size=batch_size, seq_len=128, hidden=384, layers=6, heads=6, vocab=8192)

    @staticmethod
    def m100(batch_size=2):
        """~117M params (GPT-2-small shaped): capacity demonstration."""
        return GptConfig(batch_size=batch_size, seq_len=256, hidden=768, layers=12, heads=12, vocab=32768)

    def num_params(self):
        return sum(x.size for x in jax.tree_util.tree_leaves(init_params(self, jax.random.PRNGKey(0))))


def init_params(cfg: GptConfig, key):
    """Parameter pytree (dict of arrays)."""
    h, ff = cfg.hidden, 4 * cfg.hidden
    k = iter(jax.random.split(key, 4 + 10 * cfg.layers))

    def dense(key, din, dout):
        return jax.random.normal(key, (din, dout), jnp.float32) * (din ** -0.5)

    params = {
        "wte": jax.random.normal(next(k), (cfg.vocab, h), jnp.float32) * 0.02,
        "wpe": jax.random.normal(next(k), (cfg.seq_len, h), jnp.float32) * 0.01,
        "lnf_g": jnp.ones((h,)),
        "lnf_b": jnp.zeros((h,)),
    }
    for l in range(cfg.layers):
        params[f"l{l}"] = {
            "ln1_g": jnp.ones((h,)),
            "ln1_b": jnp.zeros((h,)),
            "qkv": dense(next(k), h, 3 * h),
            "qkv_b": jnp.zeros((3 * h,)),
            "proj": dense(next(k), h, h),
            "proj_b": jnp.zeros((h,)),
            "ln2_g": jnp.ones((h,)),
            "ln2_b": jnp.zeros((h,)),
            "fc1": dense(next(k), h, ff),
            "fc1_b": jnp.zeros((ff,)),
            "fc2": dense(next(k), ff, h),
            "fc2_b": jnp.zeros((h,)),
        }
    return params


def init_opt_state(params):
    """Adam state: first/second moments + step counter."""
    zeros = lambda: jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros(), "v": zeros(), "t": jnp.float32(0.0)}


# backwards-compatible alias used by tests
init_momentum = init_opt_state


def _ln(x, g, b):
    """LayerNorm via the Pallas kernel ([B,S,H] flattened to rows)."""
    bsz, s, h = x.shape
    return ln_k.layernorm_ad(x.reshape(bsz * s, h), g, b).reshape(bsz, s, h)


def forward(cfg: GptConfig, params, x):
    """Logits [B, S, V] for token ids x [B, S]."""
    h = cfg.hidden
    tok = params["wte"][x]  # [B,S,H]
    pos = params["wpe"][None, : x.shape[1], :]
    z = tok + pos
    for l in range(cfg.layers):
        p = params[f"l{l}"]
        zn = _ln(z, p["ln1_g"], p["ln1_b"])
        qkv = zn @ p["qkv"] + p["qkv_b"]  # [B,S,3H]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        d = h // cfg.heads

        def heads(t):
            return t.reshape(t.shape[0], t.shape[1], cfg.heads, d).transpose(0, 2, 1, 3)

        a = attn_k.causal_attention_ad(heads(q), heads(k), heads(v))
        a = a.transpose(0, 2, 1, 3).reshape(z.shape)
        z = z + a @ p["proj"] + p["proj_b"]
        zn = _ln(z, p["ln2_g"], p["ln2_b"])
        f = jax.nn.gelu(zn @ p["fc1"] + p["fc1_b"])
        z = z + f @ p["fc2"] + p["fc2_b"]
    z = _ln(z, params["lnf_g"], params["lnf_b"])
    return z @ params["wte"].T  # weight-tied logits


def loss_fn(cfg: GptConfig, params, x, y):
    """Mean next-token cross-entropy."""
    logits = forward(cfg, params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1).squeeze(-1)
    return nll.mean()


def grad_step(cfg: GptConfig, params, x, y):
    """Per-worker step: (loss, grads)."""
    return jax.value_and_grad(functools.partial(loss_fn, cfg))(params, x, y)


def apply_step(cfg: GptConfig, params, state, grads, lr=2e-3, b1=0.9, b2=0.999, eps=1e-8):
    """Leader step: Adam on averaged gradients."""
    del cfg
    t = state["t"] + 1.0
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ / (1 - b1 ** t)) / (jnp.sqrt(v_ / (1 - b2 ** t)) + eps),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}


def train_step(cfg: GptConfig, params, state, x, y, lr=2e-3):
    """Fused single-worker step (quickstart path): loss + update."""
    loss, grads = grad_step(cfg, params, x, y)
    params, state = apply_step(cfg, params, state, grads, lr=lr)
    return loss, params, state


def synthetic_batch(cfg: GptConfig, key):
    """Synthetic corpus with learnable structure: token t+1 is a fixed
    affine function of token t plus noise — the LM can drive loss well
    below log(vocab) by learning the transition rule."""
    k1, k2 = jax.random.split(key)
    start = jax.random.randint(k1, (cfg.batch_size, 1), 0, cfg.vocab)
    steps = jax.random.randint(k2, (cfg.batch_size, cfg.seq_len), 0, 3)
    toks = (start + jnp.cumsum(steps * 13 + 1, axis=1)) % cfg.vocab
    x = toks[:, :-1]
    y = toks[:, 1:]
    # pad back to seq_len
    x = jnp.pad(x, ((0, 0), (1, 0)))
    y = jnp.pad(y, ((0, 0), (1, 0)))
    return x, y
