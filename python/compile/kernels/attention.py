"""L1 Pallas kernel: fused causal attention (scores → mask → softmax → ·V).

TPU-shaped (see DESIGN.md §Hardware-Adaptation): the grid iterates over
(batch·heads, query blocks); each grid step holds one (BLOCK_Q × D) query
tile in VMEM and streams the full K/V for that head — MXU-friendly matmuls
with fp32 accumulation, BlockSpec expressing the HBM↔VMEM schedule a CUDA
flash-attention kernel would express with threadblocks.

On this image Pallas must run `interpret=True` (CPU PJRT cannot execute
Mosaic custom-calls); the lowered HLO is what the Rust runtime executes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 64


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, block_q):
    """One (batch·head, q-block) grid step."""
    qi = pl.program_id(1)
    q = q_ref[...]  # [block_q, d]
    k = k_ref[...]  # [s, d]
    v = v_ref[...]  # [s, d]
    s = k.shape[0]
    # scores for this query tile against all keys (MXU matmul, fp32 acc)
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    # causal mask: query row (qi*block_q + i) attends to keys <= that row
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, s), 0)
    k_pos = jax.lax.broadcasted_iota(jnp.int32, (block_q, s), 1)
    scores = jnp.where(k_pos <= q_pos, scores, -1e30)
    # numerically-stable softmax in fp32
    m = scores.max(axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / p.sum(axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(p, v, preferred_element_type=jnp.float32).astype(o_ref.dtype)


def causal_attention(q, k, v, *, block_q=DEFAULT_BLOCK_Q, interpret=True):
    """Fused causal attention over [B, H, S, D] via a Pallas kernel.

    Shapes: S must be a multiple of block_q (callers pad otherwise).
    """
    b, h, s, d = q.shape
    block_q = min(block_q, s)
    assert s % block_q == 0, f"seq {s} not a multiple of block_q {block_q}"
    scale = 1.0 / (d ** 0.5)

    bh = b * h
    qf = q.reshape(bh, s, d)
    kf = k.reshape(bh, s, d)
    vf = v.reshape(bh, s, d)

    kernel = functools.partial(_attn_kernel, scale=scale, block_q=block_q)
    out = pl.pallas_call(
        kernel,
        grid=(bh, s // block_q),
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, s, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, s, d), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)


def vmem_bytes(s, d, block_q=DEFAULT_BLOCK_Q, dtype_bytes=4):
    """Estimated VMEM working set per grid step (DESIGN.md §Perf)."""
    q_tile = block_q * d * dtype_bytes
    kv = 2 * s * d * dtype_bytes
    scores = block_q * s * 4  # fp32 accumulator
    out = block_q * d * dtype_bytes
    return q_tile + kv + scores + out


def _auto_block(s):
    for b in (DEFAULT_BLOCK_Q, 32, 16, 8, 4, 2, 1):
        if b <= s and s % b == 0:
            return b
    return 1


@jax.custom_vjp
def causal_attention_ad(q, k, v):
    """Differentiable wrapper: Pallas kernel forward, reference-formulation
    backward (on a real TPU the backward would be a second Pallas kernel;
    both lower into the same HLO module here)."""
    return causal_attention(q, k, v, block_q=_auto_block(q.shape[2]))


def _fwd(q, k, v):
    return causal_attention_ad(q, k, v), (q, k, v)


def _bwd(res, g):
    from compile.kernels import ref

    q, k, v = res
    _, vjp = jax.vjp(ref.causal_attention, q, k, v)
    return vjp(g)


causal_attention_ad.defvjp(_fwd, _bwd)
