"""Pure-jnp oracles for the Pallas kernels (the correctness contract).

Every Pallas kernel in this package must match its reference here to
float32 tolerance; pytest + hypothesis sweep shapes and dtypes.
"""

import jax.numpy as jnp


def causal_attention(q, k, v, scale=None):
    """Causal self-attention over [B, H, S, D] tensors."""
    _, _, _, d = q.shape
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    s = q.shape[2]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(mask[None, None, :, :], scores, -1e30)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def layernorm(x, gamma, beta, eps=1e-5):
    """LayerNorm over the last axis of [..., D]."""
    mean = x.mean(axis=-1, keepdims=True)
    var = ((x - mean) ** 2).mean(axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * gamma + beta
