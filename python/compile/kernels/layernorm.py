"""L1 Pallas kernel: fused LayerNorm (mean/var/normalize/affine in one
VMEM-resident pass over row blocks)."""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 128


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)  # [rows, d]
    mean = x.mean(axis=-1, keepdims=True)
    var = ((x - mean) ** 2).mean(axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * g_ref[...] + b_ref[...]).astype(o_ref.dtype)


def layernorm(x, gamma, beta, *, eps=1e-5, block_rows=DEFAULT_BLOCK_ROWS, interpret=True):
    """LayerNorm over the last axis of [N, D] (callers flatten)."""
    n, d = x.shape
    block_rows = min(block_rows, n)
    assert n % block_rows == 0, f"rows {n} not a multiple of {block_rows}"
    kernel = functools.partial(_ln_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(x, gamma, beta)


def _auto_block(n):
    for b in (DEFAULT_BLOCK_ROWS, 64, 32, 16, 8, 4, 2, 1):
        if b <= n and n % b == 0:
            return b
    return 1


@jax.custom_vjp
def layernorm_ad(x, gamma, beta):
    """Differentiable wrapper: Pallas forward, reference backward."""
    return layernorm(x, gamma, beta, block_rows=_auto_block(x.shape[0]))


def _fwd(x, gamma, beta):
    return layernorm_ad(x, gamma, beta), (x, gamma, beta)


def _bwd(res, g):
    from compile.kernels import ref

    x, gamma, beta = res
    _, vjp = jax.vjp(ref.layernorm, x, gamma, beta)
    return vjp(g)


layernorm_ad.defvjp(_fwd, _bwd)
