"""L1 correctness: Pallas kernels vs pure-jnp oracles (the core signal).

hypothesis sweeps shapes/dtypes; assert_allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, layernorm, ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype)


class TestAttention:
    @settings(max_examples=12, deadline=None)
    @given(
        b=st.sampled_from([1, 2]),
        h=st.sampled_from([1, 2, 4]),
        s=st.sampled_from([8, 16, 32, 64]),
        d=st.sampled_from([8, 16, 32]),
    )
    def test_matches_reference(self, b, h, s, d):
        keys = jax.random.split(jax.random.PRNGKey(b * 1000 + h * 100 + s + d), 3)
        q, k, v = (rand(kk, (b, h, s, d)) for kk in keys)
        got = attention.causal_attention(q, k, v, block_q=min(16, s))
        want = ref.causal_attention(q, k, v)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_causality(self):
        """Changing future keys/values must not change earlier outputs."""
        key = jax.random.PRNGKey(0)
        q, k, v = (rand(kk, (1, 2, 32, 16)) for kk in jax.random.split(key, 3))
        base = attention.causal_attention(q, k, v, block_q=16)
        k2 = k.at[:, :, 20:, :].set(99.0)
        v2 = v.at[:, :, 20:, :].set(-99.0)
        pert = attention.causal_attention(q, k2, v2, block_q=16)
        np.testing.assert_allclose(base[:, :, :20], pert[:, :, :20], rtol=1e-5, atol=1e-5)
        assert not np.allclose(base[:, :, 21:], pert[:, :, 21:])

    def test_block_size_invariance(self):
        key = jax.random.PRNGKey(7)
        q, k, v = (rand(kk, (2, 2, 64, 16)) for kk in jax.random.split(key, 3))
        a = attention.causal_attention(q, k, v, block_q=16)
        b = attention.causal_attention(q, k, v, block_q=64)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)

    def test_rejects_ragged_seq(self):
        q = jnp.zeros((1, 1, 33, 8))
        with pytest.raises(AssertionError):
            attention.causal_attention(q, q, q, block_q=16)

    def test_softmax_rows_bounded(self):
        """Output is a convex combination of V rows."""
        key = jax.random.PRNGKey(3)
        q, k = (rand(kk, (1, 1, 32, 8)) for kk in jax.random.split(key, 2))
        v = jnp.ones((1, 1, 32, 8))
        out = attention.causal_attention(q, k, v, block_q=16)
        np.testing.assert_allclose(out, jnp.ones_like(out), rtol=1e-5, atol=1e-5)

    def test_vmem_estimate_fits_budget(self):
        # mini config: s=128, d=64 → well under 16 MB/core
        assert attention.vmem_bytes(128, 64) < 16e6
        assert attention.vmem_bytes(2048, 128) < 16e6


class TestLayerNorm:
    @settings(max_examples=10, deadline=None)
    @given(
        n=st.sampled_from([8, 64, 128, 256]),
        d=st.sampled_from([16, 64, 384]),
    )
    def test_matches_reference(self, n, d):
        key = jax.random.PRNGKey(n + d)
        x = rand(key, (n, d)) * 3.0 + 1.0
        g = rand(jax.random.fold_in(key, 1), (d,))
        b = rand(jax.random.fold_in(key, 2), (d,))
        got = layernorm.layernorm(x, g, b, block_rows=min(64, n))
        want = ref.layernorm(x, g, b)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_normalizes(self):
        x = rand(jax.random.PRNGKey(1), (64, 384)) * 10 + 5
        out = layernorm.layernorm(x, jnp.ones((384,)), jnp.zeros((384,)), block_rows=64)
        np.testing.assert_allclose(np.asarray(out).mean(axis=-1), 0.0, atol=1e-4)
        np.testing.assert_allclose(np.asarray(out).std(axis=-1), 1.0, atol=1e-3)
