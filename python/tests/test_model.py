"""L2 tests: model shapes, gradients, training dynamics, AOT lowering."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile.aot import leaf_names, to_hlo_text

jax.config.update("jax_platform_name", "cpu")

CFG = M.GptConfig.tiny()


def test_forward_shapes():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    x, y = M.synthetic_batch(CFG, jax.random.PRNGKey(1))
    assert x.shape == (CFG.batch_size, CFG.seq_len)
    logits = M.forward(CFG, params, x)
    assert logits.shape == (CFG.batch_size, CFG.seq_len, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())
    del y


def test_initial_loss_near_uniform():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    x, y = M.synthetic_batch(CFG, jax.random.PRNGKey(1))
    loss = M.loss_fn(CFG, params, x, y)
    assert abs(float(loss) - np.log(CFG.vocab)) < 1.0, float(loss)


def test_grads_cover_all_params():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    x, y = M.synthetic_batch(CFG, jax.random.PRNGKey(2))
    _, grads = M.grad_step(CFG, params, x, y)
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves)
    nonzero = sum(bool(jnp.any(g != 0)) for g in leaves)
    assert nonzero >= len(leaves) - 1  # wpe rows beyond seq may be zero


def test_loss_decreases_over_steps():
    step = jax.jit(lambda p, m, x, y: M.train_step(CFG, p, m, x, y))
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    state = M.init_opt_state(params)
    key = jax.random.PRNGKey(3)
    losses = []
    for _ in range(80):
        key, sub = jax.random.split(key)
        x, y = M.synthetic_batch(CFG, sub)
        loss, params, state = step(params, state, x, y)
        losses.append(float(loss))
    head = sum(losses[:5]) / 5
    tail = sum(losses[-5:]) / 5
    assert tail < head - 0.15, (head, tail)


def test_grad_apply_equals_fused_train_step():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    mom = M.init_momentum(params)
    x, y = M.synthetic_batch(CFG, jax.random.PRNGKey(4))
    loss_a, pa, ma = M.train_step(CFG, params, mom, x, y)
    loss_b, grads = M.grad_step(CFG, params, x, y)
    pb, mb = M.apply_step(CFG, params, mom, grads)
    assert float(loss_a) == float(loss_b)
    for a, b in zip(jax.tree_util.tree_leaves(pa), jax.tree_util.tree_leaves(pb)):
        np.testing.assert_allclose(a, b, rtol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(ma), jax.tree_util.tree_leaves(mb)):
        np.testing.assert_allclose(a, b, rtol=1e-6)


def test_leaf_names_match_flatten_order():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    names = leaf_names(params)
    leaves = jax.tree_util.tree_leaves(params)
    assert len(names) == len(leaves)
    assert "wte" in names and "l0.qkv" in names


def test_aot_lowering_produces_hlo_text():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    x, y = M.synthetic_batch(CFG, jax.random.PRNGKey(5))

    def fn(p_wte, xx, yy):
        p = dict(params)
        p["wte"] = p_wte
        return (M.loss_fn(CFG, p, xx, yy),)

    lowered = jax.jit(fn).lower(params["wte"], x, y)
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_synthetic_batch_learnable_structure():
    x, y = M.synthetic_batch(CFG, jax.random.PRNGKey(0))
    # y is x shifted left within the generated sequence
    assert bool(jnp.all(x[:, 2:] == y[:, 1:-1]))
